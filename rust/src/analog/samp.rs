//! Two-stage Summation Amplifier (2SA) — paper Fig. 4 / Section VI.
//!
//! SA1 sums the positive line currents; SA2 sums the negative line and
//! inverts SA1's output, producing
//!     V_SA = V_CAL + R_SA_p * I+ - R_SA_n * I-   (nominal)
//! Non-idealities: per-line gain errors alpha_p / alpha_n (finite open-loop
//! gain, feedback mismatch) and a combined input-referred offset beta.
//!
//! BISC trim hardware (Section VI-A): a digital potentiometer in the
//! negative feedback path tunes R_SA (per line), and a 6-bit voltage-mode
//! R-2R calibration DAC in the positive feedback loop tunes V_CAL.

use super::consts as c;

/// Digital potentiometer trimming R_SA: `POT_BITS`-bit code over
/// [R_SA_MIN, R_SA_MAX]. Default mid-scale lands on R_SA_NOM.
pub const POT_BITS: u32 = 8;
pub const POT_MAX: u32 = (1 << POT_BITS) - 1;
/// Trim range: +/-40% around nominal — wide enough to correct the paper's
/// g in ~[0.8, 1.25] (Fig. 8b) with margin.
pub const R_SA_MIN: f64 = c::R_SA_NOM * 0.6;
pub const R_SA_MAX: f64 = c::R_SA_NOM * 1.4;

/// Calibration DAC: 6-bit over [V_CAL_MIN, V_CAL_MAX].
pub const CAL_BITS: u32 = 6;
pub const CAL_MAX: u32 = (1 << CAL_BITS) - 1;
pub const V_CAL_MIN: f64 = c::V_CAL_NOM - 0.1;
pub const V_CAL_MAX: f64 = c::V_CAL_NOM + 0.1;

/// Convert a potentiometer code to a transresistance [Ohm].
pub fn pot_to_rsa(code: u32) -> f64 {
    let code = code.min(POT_MAX);
    R_SA_MIN + (R_SA_MAX - R_SA_MIN) * code as f64 / POT_MAX as f64
}

/// Nearest potentiometer code for a target transresistance.
pub fn rsa_to_pot(rsa: f64) -> u32 {
    let t = (rsa - R_SA_MIN) / (R_SA_MAX - R_SA_MIN);
    (t * POT_MAX as f64).round().clamp(0.0, POT_MAX as f64) as u32
}

/// Convert a calibration-DAC code to a voltage [V].
pub fn cal_to_vcal(code: u32) -> f64 {
    let code = code.min(CAL_MAX);
    V_CAL_MIN + (V_CAL_MAX - V_CAL_MIN) * code as f64 / CAL_MAX as f64
}

/// Nearest calibration-DAC code for a target voltage.
pub fn vcal_to_cal(v: f64) -> u32 {
    let t = (v - V_CAL_MIN) / (V_CAL_MAX - V_CAL_MIN);
    (t * CAL_MAX as f64).round().clamp(0.0, CAL_MAX as f64) as u32
}

/// One column's 2SA with its silicon errors and current trim codes.
#[derive(Debug, Clone)]
pub struct SummingAmp {
    /// positive-line gain error (SA1 path), ideally 1.0
    pub alpha_p: f64,
    /// negative-line gain error (SA2 path), ideally 1.0
    pub alpha_n: f64,
    /// combined input-referred offset [V]
    pub beta: f64,
    /// cubic distortion coefficient [V^-2]: the output is distorted as
    /// v + gamma3*(v - V_BIAS)^3 — the systematic *nonlinear* error BISC's
    /// linear correction cannot remove (the residual floor of Fig. 10)
    pub gamma3: f64,
    /// trim codes
    pub pot_p: u32,
    pub pot_n: u32,
    pub cal: u32,
    /// hard fault: output railed to a constant voltage [V] regardless of
    /// input currents or trims (amp latch-up / broken feedback). `None`
    /// for a healthy amp.
    pub stuck: Option<f64>,
}

impl Default for SummingAmp {
    fn default() -> Self {
        Self {
            alpha_p: 1.0,
            alpha_n: 1.0,
            beta: 0.0,
            gamma3: 0.0,
            pot_p: rsa_to_pot(c::R_SA_NOM),
            pot_n: rsa_to_pot(c::R_SA_NOM),
            cal: vcal_to_cal(c::V_CAL_NOM),
            stuck: None,
        }
    }
}

impl SummingAmp {
    pub fn rsa_p(&self) -> f64 {
        pot_to_rsa(self.pot_p)
    }

    pub fn rsa_n(&self) -> f64 {
        pot_to_rsa(self.pot_n)
    }

    pub fn vcal(&self) -> f64 {
        cal_to_vcal(self.cal)
    }

    /// Eq. (4) with per-line gains plus cubic distortion: the actual SA
    /// output voltage. A railed amp returns its stuck voltage no matter
    /// what flows in.
    pub fn output(&self, i_pos: f64, i_neg: f64) -> f64 {
        if let Some(v) = self.stuck {
            return v;
        }
        let v_lin = self.vcal() + self.alpha_p * self.rsa_p() * i_pos
            - self.alpha_n * self.rsa_n() * i_neg
            + self.beta;
        let d = v_lin - c::V_BIAS;
        v_lin + self.gamma3 * d * d * d
    }

    /// The output fully settles within T_S&H (Fig. 4) for the behavioural
    /// model; exposed as a check against the inference period.
    pub fn settles_within(&self, period: f64) -> bool {
        period >= c::T_SH * 0.99
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_trims_hit_nominal() {
        let sa = SummingAmp::default();
        assert!((sa.rsa_p() - c::R_SA_NOM).abs() < (R_SA_MAX - R_SA_MIN) / POT_MAX as f64);
        assert!((sa.vcal() - c::V_CAL_NOM).abs() < (V_CAL_MAX - V_CAL_MIN) / CAL_MAX as f64 / 2.0 + 1e-12);
    }

    #[test]
    fn pot_roundtrip_within_one_lsb() {
        for code in [0u32, 1, 77, 128, 200, POT_MAX] {
            let r = pot_to_rsa(code);
            assert_eq!(rsa_to_pot(r), code);
        }
        // out-of-range clamps
        assert_eq!(rsa_to_pot(0.0), 0);
        assert_eq!(rsa_to_pot(1e9), POT_MAX);
    }

    #[test]
    fn cal_roundtrip() {
        for code in [0u32, 5, 31, 32, CAL_MAX] {
            assert_eq!(vcal_to_cal(cal_to_vcal(code)), code);
        }
    }

    #[test]
    fn nominal_output_matches_eq1() {
        let sa = SummingAmp::default();
        let i = 5.0e-6;
        let v = sa.output(i, 0.0);
        let expect = sa.vcal() + sa.rsa_p() * i;
        assert!((v - expect).abs() < 1e-15);
    }

    #[test]
    fn errors_shift_output() {
        let mut sa = SummingAmp::default();
        let base = sa.output(4e-6, 2e-6);
        sa.alpha_p = 1.1;
        sa.beta = 0.005;
        let v = sa.output(4e-6, 2e-6);
        assert!(v > base);
        // offset moves output even with zero current
        assert!((sa.output(0.0, 0.0) - (sa.vcal() + 0.005)).abs() < 1e-15);
    }

    #[test]
    fn net_current_polarity() {
        let sa = SummingAmp::default();
        let above = sa.output(1e-6, 0.0);
        let below = sa.output(0.0, 1e-6);
        assert!(above > sa.vcal() && below < sa.vcal());
        // symmetric for equal currents with ideal gains
        assert!(((above - sa.vcal()) + (below - sa.vcal())).abs() < 1e-15);
    }

    #[test]
    fn stuck_amp_rails_output() {
        let sa = SummingAmp { stuck: Some(0.42), ..Default::default() };
        assert_eq!(sa.output(5e-6, 0.0), 0.42);
        assert_eq!(sa.output(0.0, 9e-6), 0.42);
        let healthy = SummingAmp::default();
        assert_ne!(healthy.output(5e-6, 0.0), healthy.output(0.0, 9e-6));
    }

    #[test]
    fn trim_range_covers_paper_gain_errors() {
        // need R_SA/alpha for alpha in [0.8, 1.25] representable
        assert!(R_SA_MIN <= c::R_SA_NOM / 1.25);
        assert!(R_SA_MAX >= c::R_SA_NOM / 0.8);
    }

    #[test]
    fn settling_flag() {
        let sa = SummingAmp::default();
        assert!(sa.settles_within(c::T_SH));
        assert!(!sa.settles_within(c::T_SH / 2.0));
    }
}
