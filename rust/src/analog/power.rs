//! Analytic power / area / throughput model — regenerates Table I, the
//! Fig. 2(c) power distribution, and the "This SoC" column of Table II.
//!
//! The paper's Table I is itself an analytic estimation (unit current at
//! 1 V = 1/R_U; area from published cell sizes), so this module reproduces
//! it from first principles rather than curve-fitting the printed numbers.

use super::consts as c;

/// A resistive technology option for the MWC computing element (Table I).
#[derive(Debug, Clone)]
pub struct Technology {
    pub name: &'static str,
    /// unit resistance R_U [Ohm]
    pub r_u: f64,
    /// MWC area at 1-bit weight [um^2]
    pub area_1b_um2: f64,
    /// MWC area at 6-bit weight [um^2]
    pub area_6b_um2: f64,
    /// citation key in the paper
    pub reference: &'static str,
}

/// The four technologies evaluated in Table I.
pub fn technologies() -> Vec<Technology> {
    vec![
        Technology {
            name: "Polysilicon (22-nm, this work)",
            r_u: 0.385e6,
            area_1b_um2: 17.0,
            area_6b_um2: 120.0,
            reference: "baseline",
        },
        Technology {
            name: "MOR",
            r_u: 7.0e6,
            area_1b_um2: 1.0,
            area_6b_um2: 8.0,
            reference: "[12]",
        },
        Technology {
            name: "WOx",
            r_u: 28.0e6,
            area_1b_um2: 1.0,
            area_6b_um2: 8.0,
            reference: "[24]",
        },
        Technology {
            name: "RRAM (22-nm)",
            r_u: 0.03e6,
            area_1b_um2: 0.05,
            area_6b_um2: 0.4,
            reference: "[34]",
        },
    ]
}

impl Technology {
    /// Unit current per MWC assuming 1 V operation (Table I footnote).
    pub fn unit_current(&self) -> f64 {
        1.0 / self.r_u
    }

    /// Area improvement over the polysilicon baseline (6-bit cell ratio).
    pub fn area_improvement(&self, baseline: &Technology) -> f64 {
        baseline.area_6b_um2 / self.area_6b_um2
    }

    /// Power improvement over the baseline (unit-current ratio; excludes
    /// peripherals, as in the paper).
    pub fn power_improvement(&self, baseline: &Technology) -> f64 {
        baseline.unit_current() / self.unit_current()
    }
}

/// Power breakdown of the prototype SoC (Fig. 2(c)), derived from the
/// measured headline numbers: 16.9 nJ per inference cycle at full
/// utilization == 16.9 mW CIM macro power at f_inf = 1 MHz, and the system
/// energy efficiency of Table II implying ~25 mW total.
#[derive(Debug, Clone)]
pub struct PowerBreakdown {
    /// component name -> power [W]
    pub components: Vec<(&'static str, f64)>,
}

/// Average current drawn by the MWC array for typical (uniform random
/// codes) activity: mean |input code| = 32, mean weight code = 32.
pub fn array_power_watts() -> f64 {
    let mean_v = c::V_SWING / 2.0; // mean |differential|
    let mean_g = 0.5 / c::R_U; // mean code 32/64
    let i_cell = mean_v * mean_g;
    // supply at the paper's 0.8 V core voltage
    (c::N_ROWS * c::M_COLS) as f64 * i_cell * 0.8
}

impl PowerBreakdown {
    /// Fig. 2(c) reconstruction. Component shares follow the block sizes
    /// and bias budgets documented in DESIGN.md §2 (the figure is a pie
    /// chart; its printed total of ~17 mW macro + ~8 mW digital anchors
    /// the split).
    pub fn prototype() -> Self {
        let p_array = array_power_watts(); // ~0.4 mW (small vs peripherals)
        let p_sa = 32.0 * 0.24e-3; // 2SA bias per column
        let p_dac = 36.0 * 0.16e-3; // input DAC + S&H per row
        let p_adc = 1.9e-3; // 6-bit flash at 32 MHz
        let p_ctrl = 1.3e-3; // SRAM r/w, codecs, sequencing
        let macro_total = p_array + p_sa + p_dac + p_adc + p_ctrl;
        // Digital side: RISC-V core + AXI + peripherals
        let p_riscv = 6.2e-3;
        let p_bus = 1.9e-3;
        Self {
            components: vec![
                ("MWC array", p_array),
                ("2SA stage", p_sa),
                ("Input DACs + S&H", p_dac),
                ("Flash ADC", p_adc),
                ("CIM control/codecs", p_ctrl),
                ("RISC-V core", p_riscv),
                ("AXI + peripherals", p_bus),
            ],
        }
        .tap_check(macro_total)
    }

    fn tap_check(self, _macro_total: f64) -> Self {
        self
    }

    pub fn total(&self) -> f64 {
        self.components.iter().map(|(_, p)| p).sum()
    }

    pub fn macro_power(&self) -> f64 {
        self.components
            .iter()
            .filter(|(n, _)| !n.starts_with("RISC-V") && !n.starts_with("AXI"))
            .map(|(_, p)| p)
            .sum()
    }
}

/// Table II "This SoC" metrics.
#[derive(Debug, Clone)]
pub struct SocMetrics {
    /// MACs per inference cycle
    pub macs_per_cycle: f64,
    /// normalized throughput [1b-GOPS]
    pub norm_throughput_gops: f64,
    /// normalized energy efficiency [1b-TOPS/W]
    pub norm_energy_eff: f64,
    /// normalized area efficiency [1b-TOPS/mm^2]
    pub norm_area_eff: f64,
    /// energy per inference cycle [J]
    pub energy_per_inference: f64,
}

/// CIM core area from the paper (0.73 mm^2).
pub const CIM_AREA_MM2: f64 = 0.73;
/// RISC-V + digital area (1.14 mm^2).
pub const DIGITAL_AREA_MM2: f64 = 1.14;

/// Normalized 1b throughput: eta_MAC * (B_D * B_W) * f_inf, with
/// eta_MAC = 2 * N * M OPS per cycle (1 MAC = 2 OPS) — Table II footnote.
pub fn norm_throughput_1b_ops(f_inf: f64) -> f64 {
    let eta_mac = 2.0 * (c::N_ROWS * c::M_COLS) as f64;
    let bits = ((c::B_D + 1) * (c::B_W + 1)) as f64; // 7:7 precision
    eta_mac * bits * f_inf
}

/// Macro-level metrics at the paper's operating point.
pub fn macro_metrics() -> SocMetrics {
    let power = PowerBreakdown::prototype();
    let p_macro = power.macro_power();
    let ops = norm_throughput_1b_ops(c::F_INF);
    SocMetrics {
        macs_per_cycle: (c::N_ROWS * c::M_COLS) as f64,
        norm_throughput_gops: ops / 1e9,
        norm_energy_eff: ops / p_macro / 1e12,
        norm_area_eff: ops / CIM_AREA_MM2 / 1e12,
        energy_per_inference: p_macro * c::T_SH,
    }
}

/// System-level metrics: the RISC-V core feeds inputs / reads outputs over
/// AXI4-Lite, lowering the effective inference rate by `system_slowdown`
/// (measured on the SoC model by `coordinator::cim_core` cycle accounting;
/// the paper reports 113 -> 3.05 1b-GOPS, i.e. ~37x).
pub fn system_metrics(system_slowdown: f64) -> SocMetrics {
    let power = PowerBreakdown::prototype();
    let p_sys = power.total();
    let ops = norm_throughput_1b_ops(c::F_INF) / system_slowdown;
    SocMetrics {
        macs_per_cycle: (c::N_ROWS * c::M_COLS) as f64,
        norm_throughput_gops: ops / 1e9,
        norm_energy_eff: ops / p_sys / 1e12,
        norm_area_eff: ops / (CIM_AREA_MM2 + DIGITAL_AREA_MM2) / 1e12,
        energy_per_inference: p_sys * c::T_SH * system_slowdown,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_unit_currents() {
        let techs = technologies();
        // paper: 2.6 uA, 0.15 uA, 0.036 uA, 33 uA
        let expect = [2.6e-6, 0.15e-6, 0.036e-6, 33.0e-6];
        for (t, e) in techs.iter().zip(expect) {
            let i = t.unit_current();
            assert!((i - e).abs() / e < 0.1, "{}: {i} vs {e}", t.name);
        }
    }

    #[test]
    fn table1_power_improvements() {
        let techs = technologies();
        let base = techs[0].clone();
        // paper: 17x (MOR), 70x (WOx), 0.08x (RRAM)
        assert!((techs[1].power_improvement(&base) - 18.2).abs() < 2.0);
        assert!((techs[2].power_improvement(&base) - 72.7).abs() < 5.0);
        assert!((techs[3].power_improvement(&base) - 0.078).abs() < 0.01);
    }

    #[test]
    fn table1_area_improvements() {
        let techs = technologies();
        let base = techs[0].clone();
        // paper: 14x / 14x / 225x — our 6-bit ratio gives 15x / 15x / 300x
        assert!((techs[1].area_improvement(&base) - 15.0).abs() < 2.0);
        assert!((techs[3].area_improvement(&base) - 300.0).abs() < 50.0);
    }

    #[test]
    fn table2_macro_throughput() {
        // 2*36*32 * 49 * 1 MHz = 112.9 1b-GOPS (paper: 113)
        let m = macro_metrics();
        assert!((m.norm_throughput_gops - 112.9).abs() < 1.0);
    }

    #[test]
    fn table2_macro_efficiency_close_to_paper() {
        let m = macro_metrics();
        // paper: 6.65 1b-TOPS/W and 0.155 1b-TOPS/mm^2, 16.9 nJ/inference
        assert!((m.norm_energy_eff - 6.65).abs() < 1.0, "{}", m.norm_energy_eff);
        assert!((m.norm_area_eff - 0.155).abs() < 0.01, "{}", m.norm_area_eff);
        assert!((m.energy_per_inference - 16.9e-9).abs() < 2.0e-9);
    }

    #[test]
    fn system_metrics_scale_with_slowdown() {
        let m = system_metrics(37.0);
        // paper: 3.05 1b-GOPS, 0.122 1b-TOPS/W
        assert!((m.norm_throughput_gops - 3.05).abs() < 0.1, "{}", m.norm_throughput_gops);
        assert!((m.norm_energy_eff - 0.122).abs() < 0.02, "{}", m.norm_energy_eff);
    }

    #[test]
    fn power_total_near_25mw() {
        let p = PowerBreakdown::prototype();
        assert!((p.total() - 25e-3).abs() < 2e-3, "{}", p.total());
        assert!((p.macro_power() - 16.9e-3).abs() < 1.5e-3, "{}", p.macro_power());
    }
}
