//! The four lint rules plus suppression hygiene (DESIGN.md §12 has the
//! rule table and rationale). Each check is a conservative token-pattern
//! match over the [`FileIndex`]: comments, strings, and `#[cfg(test)]`
//! spans never produce violations, and every rule can be suppressed per
//! site with `// lint: allow(<rule>) — <justification>`.

use super::index::FileIndex;
use super::lexer::Kind;
use super::{LintReport, Violation};

/// Every rule the engine knows, in reporting order.
pub const RULE_NAMES: [&str; 5] = [
    "panic_free",
    "hot_path_alloc",
    "lock_across_io",
    "unsafe_block_safety",
    "lint_allow_justification",
];

const PANIC_FREE: &str = RULE_NAMES[0];
const HOT_PATH_ALLOC: &str = RULE_NAMES[1];
const LOCK_ACROSS_IO: &str = RULE_NAMES[2];
const UNSAFE_SAFETY: &str = RULE_NAMES[3];
const ALLOW_JUSTIFICATION: &str = RULE_NAMES[4];

/// Files whose non-test code runs on serving threads, where a panic is a
/// silent core outage ([`PANIC_FREE`] scope).
fn serving_scope(rel: &str) -> bool {
    rel == "coordinator/batcher.rs"
        || rel == "coordinator/service.rs"
        || rel == "coordinator/cluster.rs"
        || rel == "coordinator/calibrator.rs"
        || rel == "coordinator/registry.rs"
        || rel.starts_with("coordinator/wire/")
        || rel.starts_with("soc/ctl/")
}

/// Run every rule over one indexed file, appending to `report`.
pub fn lint_file(idx: &FileIndex<'_>, report: &mut LintReport) {
    if serving_scope(&idx.rel) {
        panic_free(idx, report);
    }
    hot_path_alloc(idx, report);
    lock_across_io(idx, report);
    unsafe_block_safety(idx, report);
    allow_hygiene(idx, report);
}

/// Emit unless a justified allow covers (rule, line).
fn emit(idx: &FileIndex<'_>, report: &mut LintReport, rule: &'static str, line: usize, msg: String) {
    if idx.allowed(rule, line) {
        report.allows_used += 1;
    } else {
        report.violations.push(Violation { rule, file: idx.path.clone(), line, msg });
    }
}

// ---- rule 1: panic-freedom in serving threads ---------------------------

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

/// Keywords that can directly precede `[` without it being postfix
/// indexing (`let [a, b] = …`, `for x in [..] …`, `= match v { .. }[..]`
/// does not occur).
const NON_POSTFIX_KEYWORDS: [&str; 12] = [
    "let", "in", "return", "if", "else", "match", "mut", "ref", "move", "as", "break", "continue",
];

fn panic_free(idx: &FileIndex<'_>, report: &mut LintReport) {
    let toks = &idx.tokens;
    for (i, t) in toks.iter().enumerate() {
        if t.is_trivia() || idx.in_test(i) {
            continue;
        }
        match t.kind {
            Kind::Ident if t.text == "unwrap" || t.text == "expect" => {
                let prev_dot = idx
                    .prev_significant(i)
                    .is_some_and(|p| toks[p].kind == Kind::Punct && toks[p].text == ".");
                let next_paren = idx
                    .next_significant(i)
                    .is_some_and(|n| toks[n].kind == Kind::Punct && toks[n].text == "(");
                if prev_dot && next_paren {
                    emit(
                        idx,
                        report,
                        PANIC_FREE,
                        t.line,
                        format!(
                            "`.{}()` can panic a serving thread; route the error through \
                             ServeError/WireError instead",
                            t.text
                        ),
                    );
                }
            }
            Kind::Ident if PANIC_MACROS.contains(&t.text) => {
                let next_bang = idx
                    .next_significant(i)
                    .is_some_and(|n| toks[n].kind == Kind::Punct && toks[n].text == "!");
                if next_bang {
                    emit(
                        idx,
                        report,
                        PANIC_FREE,
                        t.line,
                        format!("`{}!` panics a serving thread; return an error instead", t.text),
                    );
                }
            }
            Kind::Punct if t.text == "[" => {
                if postfix_index(idx, i) && !const_only_brackets(idx, i) {
                    emit(
                        idx,
                        report,
                        PANIC_FREE,
                        t.line,
                        "slice indexing can panic a serving thread; use .get()/.get_mut() or a \
                         checked range"
                            .to_string(),
                    );
                }
            }
            _ => {}
        }
    }
}

/// Is the `[` at raw index `i` postfix indexing (`expr[...]`) rather than
/// an array/slice literal, type, pattern, or attribute?
fn postfix_index(idx: &FileIndex<'_>, i: usize) -> bool {
    let Some(p) = idx.prev_significant(i) else { return false };
    let prev = &idx.tokens[p];
    match prev.kind {
        Kind::Ident => !NON_POSTFIX_KEYWORDS.contains(&prev.text),
        Kind::Punct => matches!(prev.text, ")" | "]" | "?"),
        _ => false,
    }
}

/// True when every significant token between `[` and its matching `]` is
/// an integer literal or `.` — constant indices (`b[0]`) and constant
/// ranges (`b[4..12]`, `b[..]`) cannot be out of bounds by a runtime
/// value the types did not already pin.
fn const_only_brackets(idx: &FileIndex<'_>, open: usize) -> bool {
    let toks = &idx.tokens;
    let mut depth = 0usize;
    for t in toks.iter().skip(open) {
        if t.kind == Kind::Punct {
            match t.text {
                "[" => depth += 1,
                "]" => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        return true;
                    }
                    continue;
                }
                "." => continue,
                _ => return false,
            }
            continue;
        }
        if t.is_trivia() {
            continue;
        }
        if t.kind != Kind::Int {
            return false;
        }
    }
    true
}

// ---- rule 2: no allocation in `_into` kernels ---------------------------

/// Method calls that allocate.
const ALLOC_METHODS: [&str; 5] = ["to_vec", "clone", "collect", "to_string", "to_owned"];
/// `Type::ctor` pairs that allocate.
const ALLOC_CTORS: [(&str, &str); 5] = [
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("Box", "new"),
    ("String", "new"),
    ("String", "from"),
];
/// Macros that allocate.
const ALLOC_MACROS: [&str; 2] = ["vec", "format"];

fn hot_path_alloc(idx: &FileIndex<'_>, report: &mut LintReport) {
    let toks = &idx.tokens;
    for f in &idx.fns {
        if !f.name.ends_with("_into") || idx.in_test(f.body.0) {
            continue;
        }
        for i in f.body.0..=f.body.1.min(toks.len().saturating_sub(1)) {
            let t = &toks[i];
            if t.is_trivia() || t.kind != Kind::Ident {
                continue;
            }
            let next_is = |p: usize, s: &str| {
                idx.next_significant(p).is_some_and(|n| toks[n].text == s)
            };
            let hit: Option<String> = if ALLOC_MACROS.contains(&t.text) && next_is(i, "!") {
                Some(format!("{}!", t.text))
            } else if ALLOC_METHODS.contains(&t.text)
                && idx.prev_significant(i).is_some_and(|p| toks[p].text == ".")
                && next_is(i, "(")
            {
                Some(format!(".{}()", t.text))
            } else if let Some(&(ty, ctor)) =
                ALLOC_CTORS.iter().find(|&&(ty, _)| ty == t.text)
            {
                // match `Type :: ctor`
                let c1 = idx.next_significant(i);
                let c2 = c1.and_then(|n| idx.next_significant(n));
                let c3 = c2.and_then(|n| idx.next_significant(n));
                match (c1, c2, c3) {
                    (Some(a), Some(b), Some(c))
                        if toks[a].text == ":" && toks[b].text == ":" && toks[c].text == ctor =>
                    {
                        Some(format!("{ty}::{ctor}"))
                    }
                    _ => None,
                }
            } else {
                None
            };
            if let Some(what) = hit {
                emit(
                    idx,
                    report,
                    HOT_PATH_ALLOC,
                    t.line,
                    format!(
                        "allocating construct `{what}` inside `_into` kernel `{}` — the \
                         fold-time-specialized set must stay allocation-free (DESIGN.md §11)",
                        f.name
                    ),
                );
            }
        }
    }
}

// ---- rule 3: no lock guard live across blocking I/O ---------------------

/// Returns the I/O marker at `i` if the token is one: `.send(`,
/// `.recv(`, `.write_all(`, `.flush(`, or a `write_frame`/
/// `write_frame_buf` call (the repo's framed-write funnel).
fn io_marker(idx: &FileIndex<'_>, i: usize) -> Option<&'static str> {
    let toks = &idx.tokens;
    let t = &toks[i];
    if t.kind != Kind::Ident {
        return None;
    }
    let next_paren =
        idx.next_significant(i).is_some_and(|n| toks[n].kind == Kind::Punct && toks[n].text == "(");
    if !next_paren {
        return None;
    }
    let prev_dot = idx
        .prev_significant(i)
        .is_some_and(|p| toks[p].kind == Kind::Punct && toks[p].text == ".");
    match t.text {
        "send" if prev_dot => Some(".send("),
        "recv" | "recv_timeout" if prev_dot => Some(".recv("),
        "write_all" if prev_dot => Some(".write_all("),
        "flush" if prev_dot => Some(".flush("),
        "write_frame" => Some("write_frame("),
        "write_frame_buf" => Some("write_frame_buf("),
        _ => None,
    }
}

/// Is token `i` a guard-acquiring call: `.lock(`, the repo's
/// poison-tolerant `lock_unpoisoned(` helper (`util::sync`), or a
/// zero-argument `.read()` / `.write()` (the `RwLock` forms — I/O
/// `read`/`write` always take a buffer argument)?
fn lock_call(idx: &FileIndex<'_>, i: usize) -> bool {
    let toks = &idx.tokens;
    if toks[i].kind != Kind::Ident {
        return false;
    }
    let open = match idx.next_significant(i) {
        Some(n) if toks[n].text == "(" => n,
        _ => return false,
    };
    if toks[i].text == "lock_unpoisoned" {
        return true;
    }
    let prev_dot = idx
        .prev_significant(i)
        .is_some_and(|p| toks[p].kind == Kind::Punct && toks[p].text == ".");
    if !prev_dot {
        return false;
    }
    match toks[i].text {
        "lock" => true,
        "read" | "write" => {
            // zero-arg call: `(` immediately closed by `)`
            idx.next_significant(open).is_some_and(|c| toks[c].text == ")")
        }
        _ => false,
    }
}

struct Guard {
    name: String,
    /// Brace depth of the block the guard lives in: the guard dies when
    /// that block's closing `}` brings the depth below this value.
    depth: usize,
    lock_line: usize,
}

/// Per-statement accumulator for the linear scan in [`lock_across_io`].
#[derive(Default)]
struct Stmt {
    lock_line: Option<usize>,
    io: Option<(&'static str, usize)>,
    let_name: Option<String>,
    /// First significant token was `if`/`while` — a lock bound by the
    /// statement head scopes to the block it opens, not the enclosing one.
    conditional: bool,
    seen_any: bool,
}

/// Finish the current statement: a lock and an I/O marker in one
/// statement is a violation; a `let`-bound lock registers a live guard.
fn flush_stmt(
    idx: &FileIndex<'_>,
    report: &mut LintReport,
    stmt: &mut Stmt,
    guards: &mut Vec<Guard>,
    depth: usize,
    entering_block: bool,
) {
    if let (Some(lock_line), Some((what, io_line))) = (stmt.lock_line, stmt.io) {
        emit(
            idx,
            report,
            LOCK_ACROSS_IO,
            io_line,
            format!(
                "blocking `{what}` in the same statement as a lock acquired on line \
                 {lock_line} — the guard is held across the I/O"
            ),
        );
    } else if let (Some(lock_line), Some(name)) = (stmt.lock_line, stmt.let_name.take()) {
        let scope = if entering_block && stmt.conditional { depth + 1 } else { depth };
        guards.push(Guard { name, depth: scope, lock_line });
    }
    *stmt = Stmt::default();
}

fn lock_across_io(idx: &FileIndex<'_>, report: &mut LintReport) {
    let toks = &idx.tokens;
    let mut depth = 0usize;
    let mut guards: Vec<Guard> = Vec::new();
    let mut stmt = Stmt::default();
    let mut pending_let = false; // saw `let`, capturing the bound name

    for (i, t) in toks.iter().enumerate() {
        if t.is_trivia() || idx.in_test(i) {
            continue;
        }
        if !stmt.seen_any {
            stmt.seen_any = true;
            stmt.conditional = t.kind == Kind::Ident && matches!(t.text, "if" | "while");
        }
        if pending_let {
            // `let [mut] <name> = …` — only simple bindings are tracked;
            // destructuring patterns record their first binder, which is
            // enough for scope tracking even if `drop()` matching misses.
            if t.kind == Kind::Ident && t.text == "mut" {
                continue;
            }
            if t.kind == Kind::Ident {
                stmt.let_name = Some(t.text.to_string());
            }
            pending_let = false;
        }
        match t.kind {
            Kind::Punct => match t.text {
                "{" => {
                    flush_stmt(idx, report, &mut stmt, &mut guards, depth, true);
                    depth += 1;
                }
                "}" => {
                    flush_stmt(idx, report, &mut stmt, &mut guards, depth, false);
                    depth = depth.saturating_sub(1);
                    guards.retain(|g| g.depth <= depth);
                }
                ";" => {
                    flush_stmt(idx, report, &mut stmt, &mut guards, depth, false);
                }
                _ => {}
            },
            Kind::Ident => {
                if t.text == "let" {
                    pending_let = true;
                } else if t.text == "drop" {
                    // `drop(<guard>)` releases early
                    if let Some(open) = idx.next_significant(i) {
                        if toks[open].text == "(" {
                            if let Some(arg) = idx.next_significant(open) {
                                let name = toks[arg].text;
                                guards.retain(|g| g.name != name);
                            }
                        }
                    }
                } else if lock_call(idx, i) {
                    if stmt.lock_line.is_none() {
                        stmt.lock_line = Some(t.line);
                    }
                } else if let Some(what) = io_marker(idx, i) {
                    if stmt.io.is_none() {
                        stmt.io = Some((what, t.line));
                    }
                    if stmt.lock_line.is_none() {
                        if let Some(g) = guards.last() {
                            emit(
                                idx,
                                report,
                                LOCK_ACROSS_IO,
                                t.line,
                                format!(
                                    "blocking `{what}` while guard `{}` (locked on line {}) is \
                                     still live — drop it before the I/O",
                                    g.name, g.lock_line
                                ),
                            );
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

// ---- rule 4: unsafe blocks carry SAFETY comments ------------------------

fn unsafe_block_safety(idx: &FileIndex<'_>, report: &mut LintReport) {
    let toks = &idx.tokens;
    for (i, t) in toks.iter().enumerate() {
        if t.is_trivia() || idx.in_test(i) {
            continue;
        }
        if t.kind != Kind::Ident || t.text != "unsafe" {
            continue;
        }
        // `unsafe {` only — `unsafe fn`/`unsafe impl` document at the item
        let opens_block =
            idx.next_significant(i).is_some_and(|n| toks[n].text == "{");
        if !opens_block {
            continue;
        }
        let documented = toks.iter().any(|c| {
            c.is_trivia()
                && c.text.contains("SAFETY:")
                && c.line + 3 >= t.line
                && c.line <= t.line
        });
        if !documented {
            emit(
                idx,
                report,
                UNSAFE_SAFETY,
                t.line,
                "`unsafe` block without a `// SAFETY:` comment on the block or the lines \
                 directly above"
                    .to_string(),
            );
        }
    }
}

// ---- suppression hygiene ------------------------------------------------

fn allow_hygiene(idx: &FileIndex<'_>, report: &mut LintReport) {
    for a in &idx.allows {
        if !RULE_NAMES.contains(&a.rule.as_str()) {
            report.violations.push(Violation {
                rule: ALLOW_JUSTIFICATION,
                file: idx.path.clone(),
                line: a.line,
                msg: format!("`lint: allow({})` names a rule the engine does not have", a.rule),
            });
        } else if !a.justified {
            report.violations.push(Violation {
                rule: ALLOW_JUSTIFICATION,
                file: idx.path.clone(),
                line: a.line,
                msg: format!(
                    "`lint: allow({})` without a justification — every suppression must say why",
                    a.rule
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lint_sources;

    fn rules_hit(path: &str, src: &str) -> Vec<&'static str> {
        lint_sources(&[(path, src)]).violations.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn unwrap_flagged_only_in_serving_scope() {
        let src = "fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n";
        assert_eq!(rules_hit("coordinator/batcher.rs", src), vec![PANIC_FREE]);
        assert_eq!(
            rules_hit("soc/ctl/periph.rs", src),
            vec![PANIC_FREE],
            "the firmware supervisor runs on the calibrator thread: serving scope"
        );
        assert!(rules_hit("analog/mod.rs", src).is_empty());
        assert!(rules_hit("soc/firmware.rs", src).is_empty(), "offline soc code is out of scope");
    }

    #[test]
    fn const_indexing_passes_dynamic_indexing_fails() {
        let ok = "fn f(h: &[u8; 16]) -> u8 { h[0] ^ h[12] }\n\
                  fn g(h: &[u8]) -> &[u8] { &h[4..12] }\n";
        assert!(rules_hit("coordinator/wire/codec.rs", ok).is_empty());
        let bad = "fn f(h: &[u8], i: usize) -> u8 { h[i] }\n";
        assert_eq!(rules_hit("coordinator/wire/codec.rs", bad), vec![PANIC_FREE]);
    }

    #[test]
    fn justified_allow_suppresses_and_counts() {
        let src = "fn f(h: &[u8], at: usize) -> u8 {\n    // lint: allow(panic_free) — bounds \
                   checked by caller\n    h[at]\n}\n";
        let report = lint_sources(&[("coordinator/wire/codec.rs", src)]);
        assert!(report.clean(), "{:?}", report.violations);
        assert_eq!(report.allows_used, 1);
    }

    #[test]
    fn alloc_in_into_kernel_flagged_everywhere() {
        let src = "pub fn forward_batch_into(x: &[i32], out: &mut Vec<u32>) {\n    let tmp: \
                   Vec<i32> = x.to_vec();\n    out.push(tmp.len() as u32);\n}\n";
        assert_eq!(rules_hit("analog/mod.rs", src), vec![HOT_PATH_ALLOC]);
        let ok = "pub fn forward_batch_into(x: &[i32], out: &mut Vec<u32>) {\n    \
                  out.resize(x.len(), 0);\n    out.clear();\n}\n";
        assert!(rules_hit("analog/mod.rs", ok).is_empty());
    }

    #[test]
    fn lock_across_send_same_statement() {
        let src = "fn f(m: &std::sync::Mutex<u32>, tx: &Sender<u32>) {\n    \
                   tx.send(*m.lock().unwrap_or_else(|p| p.into_inner())).ok();\n}\n";
        assert_eq!(rules_hit("runtime/mod.rs", src), vec![LOCK_ACROSS_IO]);
    }

    #[test]
    fn let_guard_live_across_write_all_flagged_drop_clears() {
        let bad = "fn f(m: &Mutex<W>, out: &mut O) {\n    let g = m.lock();\n    \
                   out.write_all(b\"x\");\n}\n";
        assert_eq!(rules_hit("runtime/mod.rs", bad), vec![LOCK_ACROSS_IO]);
        let ok = "fn f(m: &Mutex<W>, out: &mut O) {\n    let g = m.lock();\n    drop(g);\n    \
                  out.write_all(b\"x\");\n}\n";
        assert!(rules_hit("runtime/mod.rs", ok).is_empty());
        let scoped = "fn f(m: &Mutex<W>, out: &mut O) {\n    { let g = m.lock(); }\n    \
                      out.write_all(b\"x\");\n}\n";
        assert!(rules_hit("runtime/mod.rs", scoped).is_empty());
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let bad = "fn f() { unsafe { core::hint::unreachable_unchecked() } }\n";
        assert_eq!(rules_hit("soc/mod.rs", bad), vec![UNSAFE_SAFETY]);
        let ok = "fn f() {\n    // SAFETY: caller guarantees the invariant\n    unsafe { \
                  core::hint::unreachable_unchecked() }\n}\n";
        assert!(rules_hit("soc/mod.rs", ok).is_empty());
    }

    #[test]
    fn unjustified_or_unknown_allow_is_a_violation() {
        let src = "fn f() {} // lint: allow(panic_free)\n";
        assert_eq!(rules_hit("analog/mod.rs", src), vec![ALLOW_JUSTIFICATION]);
        let unknown = "fn f() {} // lint: allow(panic_freee) — typo\n";
        assert_eq!(rules_hit("analog/mod.rs", unknown), vec![ALLOW_JUSTIFICATION]);
    }

    #[test]
    fn test_mod_code_is_exempt() {
        let src = "fn live() -> u32 { 1 }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { \
                   assert_eq!(super::live(), vec![1][0]); x.unwrap(); }\n}\n";
        assert!(rules_hit("coordinator/batcher.rs", src).is_empty());
    }
}
