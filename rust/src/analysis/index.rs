//! Per-file index over the lexed token stream: `#[cfg(test)]` spans
//! (exempt from the rules — test code may panic freely), function items
//! with their body token ranges (rule scoping for the `_into` kernel
//! set), and parsed `// lint: allow(...)` suppression comments.

use super::lexer::{lex, Kind, Token};

/// A function item: `name` plus the raw-token index range of its body
/// (inclusive of both braces). Trait method declarations without a body
/// are not recorded.
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Raw token indices `[open_brace, close_brace]` of the body.
    pub body: (usize, usize),
    pub line: usize,
}

/// One parsed `// lint: allow(<rule>) — <justification>` comment.
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub line: usize,
    /// False when no justification text follows the `)` — itself a
    /// violation (`lint_allow_justification`).
    pub justified: bool,
}

/// Everything the rules need to know about one source file.
pub struct FileIndex<'a> {
    /// Path exactly as handed to the linter.
    pub path: String,
    /// Path portion after the last `src/` separator (or the whole path)
    /// — what rule scoping matches against, so real paths
    /// (`rust/src/coordinator/batcher.rs`) and fixture virtual paths
    /// (`coordinator/batcher.rs`) behave identically.
    pub rel: String,
    pub tokens: Vec<Token<'a>>,
    /// Raw-token index ranges (inclusive) of `#[cfg(test)] mod` items.
    pub test_spans: Vec<(usize, usize)>,
    pub fns: Vec<FnItem>,
    pub allows: Vec<Allow>,
}

impl<'a> FileIndex<'a> {
    pub fn build(path: &str, text: &'a str) -> Self {
        let tokens = lex(text);
        let rel = match path.rfind("src/") {
            Some(at) => path[at + 4..].to_string(),
            None => path.to_string(),
        };
        let test_spans = find_test_spans(&tokens);
        let fns = find_fns(&tokens);
        let allows = find_allows(&tokens);
        Self { path: path.to_string(), rel, tokens, test_spans, fns, allows }
    }

    /// True when raw token index `i` lies inside a `#[cfg(test)] mod`.
    pub fn in_test(&self, i: usize) -> bool {
        self.test_spans.iter().any(|&(lo, hi)| lo <= i && i <= hi)
    }

    /// Index of the previous non-trivia token before raw index `i`.
    pub fn prev_significant(&self, i: usize) -> Option<usize> {
        (0..i).rev().find(|&j| !self.tokens[j].is_trivia())
    }

    /// Index of the next non-trivia token after raw index `i`.
    pub fn next_significant(&self, i: usize) -> Option<usize> {
        (i + 1..self.tokens.len()).find(|&j| !self.tokens[j].is_trivia())
    }

    /// A justified allow for `rule` on `line` or the line directly above.
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows.iter().any(|a| {
            a.justified && a.rule == rule && (a.line == line || a.line + 1 == line)
        })
    }
}

/// Match the raw-token suffix `# [ cfg ( test ) ]` ending at `close`,
/// i.e. decide whether the attribute list just closed is `#[cfg(test)]`.
fn is_cfg_test_attr(tokens: &[Token<'_>], open: usize, close: usize) -> bool {
    let inner: Vec<&str> = tokens[open + 1..close]
        .iter()
        .filter(|t| !t.is_trivia())
        .map(|t| t.text)
        .collect();
    inner == ["cfg", "(", "test", ")"]
}

/// Find `#[cfg(test)] mod <name> { … }` spans; the span covers the `#`
/// through the matching close brace.
fn find_test_spans(tokens: &[Token<'_>]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if tokens[i].kind == Kind::Punct && tokens[i].text == "#" {
            if let Some((attr_open, attr_close)) = attr_brackets(tokens, i) {
                if is_cfg_test_attr(tokens, attr_open, attr_close) {
                    // skip any further attributes between cfg(test) and the item
                    let mut j = attr_close + 1;
                    while j < tokens.len()
                        && tokens[j].kind == Kind::Punct
                        && tokens[j].text == "#"
                    {
                        match attr_brackets(tokens, j) {
                            Some((_, c)) => j = c + 1,
                            None => break,
                        }
                    }
                    j = skip_trivia(tokens, j);
                    if j < tokens.len() && tokens[j].text == "mod" {
                        if let Some(open) =
                            (j..tokens.len()).find(|&k| tokens[k].text == "{")
                        {
                            if let Some(close) = match_brace(tokens, open) {
                                spans.push((i, close));
                                i = close + 1;
                                continue;
                            }
                        }
                    }
                }
                i = attr_close + 1;
                continue;
            }
        }
        i += 1;
    }
    spans
}

/// For a `#` at `at`, return the `[`/`]` raw indices of its attribute
/// bracket list.
fn attr_brackets(tokens: &[Token<'_>], at: usize) -> Option<(usize, usize)> {
    let open = skip_trivia(tokens, at + 1);
    if open >= tokens.len() || tokens[open].text != "[" {
        return None;
    }
    let mut depth = 0usize;
    for (k, t) in tokens.iter().enumerate().skip(open) {
        if t.kind != Kind::Punct {
            continue;
        }
        match t.text {
            "[" => depth += 1,
            "]" => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return Some((open, k));
                }
            }
            _ => {}
        }
    }
    None
}

fn skip_trivia(tokens: &[Token<'_>], mut i: usize) -> usize {
    while i < tokens.len() && tokens[i].is_trivia() {
        i += 1;
    }
    i
}

/// Given the raw index of a `{`, return the raw index of its matching
/// `}` (None when unbalanced).
pub fn match_brace(tokens: &[Token<'_>], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, t) in tokens.iter().enumerate().skip(open) {
        if t.kind != Kind::Punct {
            continue;
        }
        match t.text {
            "{" => depth += 1,
            "}" => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return Some(k);
                }
            }
            _ => {}
        }
    }
    None
}

/// Find every `fn <name> … { … }` item and its body token range. The
/// body opener is the first `{` after the name at parenthesis depth 0;
/// a `;` at depth 0 first means a bodyless trait declaration.
fn find_fns(tokens: &[Token<'_>]) -> Vec<FnItem> {
    let mut fns = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if tokens[i].kind == Kind::Ident && tokens[i].text == "fn" {
            let name_at = skip_trivia(tokens, i + 1);
            if name_at < tokens.len() && tokens[name_at].kind == Kind::Ident {
                let mut paren = 0isize;
                let mut k = name_at + 1;
                let mut body = None;
                while k < tokens.len() {
                    let t = &tokens[k];
                    if t.kind == Kind::Punct {
                        match t.text {
                            "(" => paren += 1,
                            ")" => paren -= 1,
                            ";" if paren == 0 => break,
                            "{" if paren == 0 => {
                                body = match_brace(tokens, k).map(|close| (k, close));
                                break;
                            }
                            _ => {}
                        }
                    }
                    k += 1;
                }
                if let Some(range) = body {
                    fns.push(FnItem {
                        name: tokens[name_at].text.to_string(),
                        body: range,
                        line: tokens[i].line,
                    });
                    // do not skip past the body: nested fns are indexed too
                }
            }
            i = name_at + 1;
            continue;
        }
        i += 1;
    }
    fns
}

/// Parse `lint: allow(<rule>)` suppressions out of line comments. The
/// justification is whatever non-separator text follows the `)`.
fn find_allows(tokens: &[Token<'_>]) -> Vec<Allow> {
    let mut allows = Vec::new();
    for t in tokens {
        if t.kind != Kind::LineComment {
            continue;
        }
        let Some(lint_at) = t.text.find("lint:") else { continue };
        let rest = &t.text[lint_at + 5..];
        let Some(allow_at) = rest.find("allow(") else { continue };
        let after_open = &rest[allow_at + 6..];
        let Some(close) = after_open.find(')') else { continue };
        let rule = after_open[..close].trim().to_string();
        // Only well-formed rule names count as suppressions; prose like
        // `allow(<rule>)` in doc comments must not parse as one.
        if rule.is_empty()
            || !rule.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            continue;
        }
        let tail = after_open[close + 1..]
            .trim_start_matches(|c: char| {
                c.is_whitespace() || matches!(c, '—' | '–' | '-' | ':' | ',')
            })
            .trim();
        allows.push(Allow { rule, line: t.line, justified: !tail.is_empty() });
    }
    allows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_test_mod_span_is_exempt() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let idx = FileIndex::build("coordinator/batcher.rs", src);
        assert_eq!(idx.test_spans.len(), 1);
        let unwrap_at = idx
            .tokens
            .iter()
            .position(|t| t.text == "unwrap")
            .expect("unwrap token present");
        assert!(idx.in_test(unwrap_at));
        let after_at = idx.tokens.iter().position(|t| t.text == "after").unwrap();
        assert!(!idx.in_test(after_at));
    }

    #[test]
    fn fn_bodies_are_ranged_and_declarations_skipped() {
        let src = "trait T { fn rows(&self) -> usize; fn go(&self) { work(); } }\n\
                   pub fn forward_batch_into(x: &[i32], out: &mut Vec<u32>) { out.clear(); }\n";
        let idx = FileIndex::build("analog/mod.rs", src);
        let names: Vec<&str> = idx.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["go", "forward_batch_into"]);
        let f = &idx.fns[1];
        assert_eq!(idx.tokens[f.body.0].text, "{");
        assert_eq!(idx.tokens[f.body.1].text, "}");
    }

    #[test]
    fn allow_comments_parse_with_and_without_justification() {
        let src = "a(); // lint: allow(panic_free) — startup-only, before serving\n\
                   b(); // lint: allow(lock_across_io)\n";
        let idx = FileIndex::build("x.rs", src);
        assert_eq!(idx.allows.len(), 2);
        assert!(idx.allows[0].justified);
        assert_eq!(idx.allows[0].rule, "panic_free");
        assert!(!idx.allows[1].justified);
        assert!(idx.allowed("panic_free", 1));
        assert!(idx.allowed("panic_free", 2), "allow reaches the next line");
        assert!(!idx.allowed("lock_across_io", 2), "unjustified allow suppresses nothing");
    }

    #[test]
    fn rel_path_strips_through_src() {
        let idx = FileIndex::build("rust/src/coordinator/wire/server.rs", "");
        assert_eq!(idx.rel, "coordinator/wire/server.rs");
        let idx2 = FileIndex::build("coordinator/batcher.rs", "");
        assert_eq!(idx2.rel, "coordinator/batcher.rs");
    }
}
