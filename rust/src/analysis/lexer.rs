//! Minimal Rust lexer for the lint rules (syn/proc-macro2 are not
//! vendored). Produces a flat token stream with source lines; enough
//! fidelity that the rules never mistake a string literal, comment, or
//! lifetime for code. Not a full grammar: shebangs, `c"…"` literals, and
//! other exotica simply lex as punctuation/unknown, which is safe for
//! rule matching (rules key on identifiers and bracket structure).

/// Token classes the rules discriminate on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (including raw `r#ident`).
    Ident,
    /// `'a`, `'static` — disambiguated from char literals.
    Lifetime,
    /// Integer literal (no `.`), e.g. `42`, `0xAC1E`, `1_000u64`.
    Int,
    /// Float literal, e.g. `1.5e3`.
    Float,
    /// Any string-ish literal: `"…"`, `r#"…"#`, `b"…"`, `'c'`, `b'c'`.
    Literal,
    /// `// …` line comment (text includes the `//`).
    LineComment,
    /// `/* … */` block comment, nesting handled (text includes markers).
    BlockComment,
    /// Single punctuation character: `. ( ) [ ] { } ; : ! # ? & …`.
    Punct,
}

/// One lexed token borrowing the source text.
#[derive(Debug, Clone, Copy)]
pub struct Token<'a> {
    pub kind: Kind,
    pub text: &'a str,
    /// 1-based line of the token's first byte.
    pub line: usize,
}

impl Token<'_> {
    /// True for tokens the rules should skip when matching code patterns.
    pub fn is_trivia(&self) -> bool {
        matches!(self.kind, Kind::LineComment | Kind::BlockComment)
    }
}

/// Lex `src` into tokens. Total: any byte sequence produces a token
/// stream (malformed input degrades to `Punct`/`Literal` tokens rather
/// than failing — the linter must never refuse to scan a file).
pub fn lex(src: &str) -> Vec<Token<'_>> {
    Lexer { b: src.as_bytes(), src, i: 0, line: 1 }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    src: &'a str,
    i: usize,
    line: usize,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Vec<Token<'a>> {
        let mut out = Vec::new();
        while let Some(c) = self.peek() {
            let start = self.i;
            let line = self.line;
            let kind = match c {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                    continue;
                }
                b'/' if self.peek_at(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek_at(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string(),
                b'\'' => self.quote(),
                b'b' | b'r' if self.literal_prefix() => self.prefixed_literal(),
                c if c == b'_' || c.is_ascii_alphabetic() => self.ident(),
                c if c.is_ascii_digit() => self.number(),
                _ => {
                    self.bump();
                    Kind::Punct
                }
            };
            out.push(Token { kind, text: &self.src[start..self.i], line });
        }
        out
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn peek_at(&self, off: usize) -> Option<u8> {
        self.b.get(self.i + off).copied()
    }

    fn bump(&mut self) {
        if self.peek() == Some(b'\n') {
            self.line += 1;
        }
        self.i += 1;
    }

    fn line_comment(&mut self) -> Kind {
        while let Some(c) = self.peek() {
            if c == b'\n' {
                break;
            }
            self.bump();
        }
        Kind::LineComment
    }

    fn block_comment(&mut self) -> Kind {
        self.bump(); // /
        self.bump(); // *
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(), self.peek_at(1)) {
                (Some(b'/'), Some(b'*')) => {
                    depth += 1;
                    self.bump();
                    self.bump();
                }
                (Some(b'*'), Some(b'/')) => {
                    depth -= 1;
                    self.bump();
                    self.bump();
                }
                (Some(_), _) => self.bump(),
                (None, _) => break, // unterminated: scan to EOF
            }
        }
        Kind::BlockComment
    }

    /// Double-quoted string with escapes.
    fn string(&mut self) -> Kind {
        self.bump(); // opening "
        while let Some(c) = self.peek() {
            match c {
                b'\\' => {
                    self.bump();
                    if self.peek().is_some() {
                        self.bump();
                    }
                }
                b'"' => {
                    self.bump();
                    break;
                }
                _ => self.bump(),
            }
        }
        Kind::Literal
    }

    /// `'` starts either a lifetime (`'a`, `'static`) or a char literal
    /// (`'x'`, `'\n'`, `'\u{1F600}'`). Lifetime iff the next char starts
    /// an identifier and the char after it does not close a quote —
    /// `'a'` is a char, `'a` followed by anything else is a lifetime.
    fn quote(&mut self) -> Kind {
        self.bump(); // '
        match self.peek() {
            Some(c) if c == b'_' || c.is_ascii_alphabetic() => {
                if self.peek_at(1) == Some(b'\'') {
                    self.bump(); // the char
                    self.bump(); // closing '
                    Kind::Literal
                } else {
                    while let Some(c) = self.peek() {
                        if c == b'_' || c.is_ascii_alphanumeric() {
                            self.bump();
                        } else {
                            break;
                        }
                    }
                    Kind::Lifetime
                }
            }
            Some(b'\\') => {
                self.bump();
                if self.peek().is_some() {
                    self.bump(); // escape head (n, t, u, ', \, …)
                }
                // consume up to the closing quote (covers \u{…})
                while let Some(c) = self.peek() {
                    self.bump();
                    if c == b'\'' {
                        break;
                    }
                }
                Kind::Literal
            }
            Some(_) => {
                self.bump(); // the char (possibly multi-byte; close below)
                while let Some(c) = self.peek() {
                    let done = c == b'\'';
                    self.bump();
                    if done {
                        break;
                    }
                }
                Kind::Literal
            }
            None => Kind::Punct,
        }
    }

    /// True when the `b`/`r` at the cursor starts a literal
    /// (`b"`, `b'`, `br`, `r"`, `r#"`) rather than an identifier. Raw
    /// identifiers (`r#match`) are NOT literals and return false.
    fn literal_prefix(&self) -> bool {
        let c0 = self.peek();
        match (c0, self.peek_at(1)) {
            (Some(b'b'), Some(b'"')) | (Some(b'b'), Some(b'\'')) => true,
            (Some(b'b'), Some(b'r')) => {
                matches!(self.peek_at(2), Some(b'"') | Some(b'#'))
            }
            (Some(b'r'), Some(b'"')) => true,
            (Some(b'r'), Some(b'#')) => {
                // r#"…"# raw string vs r#ident raw identifier: a raw
                // string's hashes are followed by `"`.
                let mut j = 1;
                while self.peek_at(j) == Some(b'#') {
                    j += 1;
                }
                self.peek_at(j) == Some(b'"')
            }
            _ => false,
        }
    }

    /// Lex `b"…"`, `b'…'`, `r"…"`, `r#"…"#`, `br#"…"#`.
    fn prefixed_literal(&mut self) -> Kind {
        if self.peek() == Some(b'b') {
            self.bump();
        }
        match self.peek() {
            Some(b'\'') => self.quote_char_only(),
            Some(b'"') => self.string(),
            Some(b'r') => {
                self.bump();
                self.raw_string()
            }
            Some(b'#') => self.raw_string(),
            _ => Kind::Literal,
        }
    }

    /// Byte-char body after `b` (always a char literal, never a lifetime).
    fn quote_char_only(&mut self) -> Kind {
        self.bump(); // '
        while let Some(c) = self.peek() {
            if c == b'\\' {
                self.bump();
                if self.peek().is_some() {
                    self.bump();
                }
            } else {
                let done = c == b'\'';
                self.bump();
                if done {
                    break;
                }
            }
        }
        Kind::Literal
    }

    /// Raw string body starting at the `#`s or `"` (the `r` is consumed).
    fn raw_string(&mut self) -> Kind {
        let mut hashes = 0usize;
        while self.peek() == Some(b'#') {
            hashes += 1;
            self.bump();
        }
        if self.peek() != Some(b'"') {
            return Kind::Literal; // malformed; degrade gracefully
        }
        self.bump(); // opening "
        'scan: while let Some(c) = self.peek() {
            self.bump();
            if c == b'"' {
                for j in 0..hashes {
                    if self.peek_at(j) != Some(b'#') {
                        continue 'scan;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
        }
        Kind::Literal
    }

    fn ident(&mut self) -> Kind {
        // raw identifier prefix r# (literal_prefix already excluded r#")
        if self.peek() == Some(b'r') && self.peek_at(1) == Some(b'#') {
            self.bump();
            self.bump();
        }
        while let Some(c) = self.peek() {
            if c == b'_' || c.is_ascii_alphanumeric() {
                self.bump();
            } else {
                break;
            }
        }
        Kind::Ident
    }

    fn number(&mut self) -> Kind {
        let mut float = false;
        // digits, underscores, hex/bin/oct bodies, and type suffixes all
        // continue the token; `1..2` must lex as Int `.` `.` Int.
        while let Some(c) = self.peek() {
            if c == b'_' || c.is_ascii_alphanumeric() {
                self.bump();
            } else if c == b'.'
                && self.peek_at(1).is_some_and(|d| d.is_ascii_digit())
                && !float
            {
                float = true;
                self.bump();
            } else if (c == b'+' || c == b'-')
                && matches!(self.b.get(self.i.wrapping_sub(1)), Some(b'e') | Some(b'E'))
                && float
            {
                self.bump(); // exponent sign in 1.5e-3
            } else {
                break;
            }
        }
        if float {
            Kind::Float
        } else {
            Kind::Int
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, &str)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_and_numbers() {
        let t = kinds("let x = v[i] + 0xAC1E;");
        assert_eq!(
            t,
            vec![
                (Kind::Ident, "let"),
                (Kind::Ident, "x"),
                (Kind::Punct, "="),
                (Kind::Ident, "v"),
                (Kind::Punct, "["),
                (Kind::Ident, "i"),
                (Kind::Punct, "]"),
                (Kind::Punct, "+"),
                (Kind::Int, "0xAC1E"),
                (Kind::Punct, ";"),
            ]
        );
    }

    #[test]
    fn range_is_int_dot_dot_int() {
        let t = kinds("a[1..20]");
        assert_eq!(
            t,
            vec![
                (Kind::Ident, "a"),
                (Kind::Punct, "["),
                (Kind::Int, "1"),
                (Kind::Punct, "."),
                (Kind::Punct, "."),
                (Kind::Int, "20"),
                (Kind::Punct, "]"),
            ]
        );
        assert_eq!(kinds("1.5e-3"), vec![(Kind::Float, "1.5e-3")]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let t = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes: Vec<_> =
            t.iter().filter(|(k, _)| *k == Kind::Lifetime).map(|(_, s)| *s).collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let lits: Vec<_> =
            t.iter().filter(|(k, _)| *k == Kind::Literal).map(|(_, s)| *s).collect();
        assert_eq!(lits, vec!["'x'", "'\\n'"]);
    }

    #[test]
    fn strings_and_raw_strings_hide_code() {
        // none of the unwraps inside literals/comments may surface as Ident
        let src = r####"let s = "x.unwrap()"; let r = r#"y.unwrap()"#; // z.unwrap()
            /* nested /* block */ a.unwrap() */ let b = b"u.unwrap()";"####;
        let idents: Vec<_> = lex(src)
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text)
            .collect();
        assert!(!idents.contains(&"unwrap"), "idents: {idents:?}");
        assert!(idents.contains(&"let"));
    }

    #[test]
    fn raw_ident_is_ident_not_literal() {
        let t = kinds("let r#match = 1;");
        assert!(t.contains(&(Kind::Ident, "r#match")));
    }

    #[test]
    fn comments_carry_text_and_lines() {
        let toks = lex("a\n// lint: allow(x) — why\nb /* multi\nline */ c");
        let comment = toks.iter().find(|t| t.kind == Kind::LineComment).unwrap();
        assert!(comment.text.contains("lint: allow"));
        assert_eq!(comment.line, 2);
        let c_tok = toks.iter().find(|t| t.text == "c").unwrap();
        assert_eq!(c_tok.line, 4);
    }

    #[test]
    fn unterminated_input_still_lexes() {
        assert!(!lex("let s = \"oops").is_empty());
        assert!(!lex("/* never closed").is_empty());
        assert!(!lex("r#\"raw").is_empty());
    }
}
