//! `acore-lint`: an in-repo static invariant checker (DESIGN.md §12).
//!
//! The serving stack depends on properties the compiler does not enforce:
//! a `unwrap()` panic inside a batcher worker silently kills that core's
//! dispatch loop, a stray allocation in an `_into` kernel undoes the
//! zero-alloc steady state pinned by `tests/alloc_steady_state.rs`, and a
//! mutex guard held across blocking wire I/O stalls every connection
//! sharing the lock. This module enforces those invariants *statically*,
//! in the repo's hand-rolled zero-dependency idiom (like [`crate::util::json`]):
//! a lightweight Rust lexer ([`lexer`]), a per-file indexer that maps out
//! `#[cfg(test)]` spans, function bodies, and suppression comments
//! ([`index`]), and a rule engine ([`rules`]) with four project-specific
//! rules:
//!
//! | rule                     | invariant pinned                                      |
//! |--------------------------|-------------------------------------------------------|
//! | `panic_free`             | serving threads never panic — no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/`unimplemented!`/`[i]`-indexing in non-test code of `coordinator/{batcher,service,cluster,calibrator,registry}.rs` and `coordinator/wire/*`; errors flow through `ServeError`/`WireError` |
//! | `hot_path_alloc`         | fold-time-specialized `*_into` kernels stay allocation-free — no `Vec::new`/`vec!`/`to_vec`/`clone`/`collect`/`format!`/`Box::new`/`to_string`/`to_owned`/`with_capacity` in their bodies (amortized `reserve`/`resize`/`push` are allowed; the runtime complement is the counting-allocator gate) |
//! | `lock_across_io`         | no `Mutex`/`RwLock` guard live across `.send(`/`.recv(`/`write_all`/`flush`/`write_frame*` — blocking I/O under a lock serializes every peer |
//! | `unsafe_block_safety`    | every `unsafe` block carries a `// SAFETY:` comment     |
//!
//! Deliberate exceptions are suppressed per site with
//! `// lint: allow(<rule>) — <justification>` on the violating line or the
//! line above. The justification text is mandatory: an allow without one
//! is itself a violation (`lint_allow_justification`), so every
//! suppression documents *why* the invariant bends there.
//!
//! Run it as `acore-cim lint [--json]`; CI runs it as a required job and
//! additionally proves the gate fires by seeding a violation and
//! asserting a non-zero exit.

pub mod index;
pub mod lexer;
pub mod rules;

use std::fmt;
use std::path::Path;

pub use index::FileIndex;
pub use rules::{lint_file, RULE_NAMES};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Rule identifier (one of [`RULE_NAMES`]).
    pub rule: &'static str,
    /// Path as given to the linter (repo-relative in CLI use).
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// Human-readable description of the violating construct.
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Aggregate result of linting a set of files.
#[derive(Debug, Default)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
    /// Number of `lint: allow` suppressions that matched a would-be
    /// violation (reported so dead allows are visible in `--json`).
    pub allows_used: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Render as a JSON document (hand-rolled; see `util/json.rs` for the
    /// matching parser). Stable field order for diffable CI artifacts.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        out.push_str(&format!("  \"allows_used\": {},\n", self.allows_used));
        out.push_str(&format!("  \"violation_count\": {},\n", self.violations.len()));
        out.push_str("  \"violations\": [\n");
        for (i, v) in self.violations.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"rule\": {}, \"file\": {}, \"line\": {}, \"msg\": {}}}{}\n",
                json_str(v.rule),
                json_str(&v.file),
                v.line,
                json_str(&v.msg),
                if i + 1 < self.violations.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Minimal JSON string escaping (mirrors `util::bench::json_str`).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Lint in-memory sources as `(virtual_path, text)` pairs. The virtual
/// path decides rule scope exactly like a real path would (e.g.
/// `"coordinator/batcher.rs"` opts into the `panic_free` serving set).
/// This is the engine entry the fixture tests drive.
pub fn lint_sources(files: &[(&str, &str)]) -> LintReport {
    let mut report = LintReport::default();
    for (path, text) in files {
        let idx = FileIndex::build(path, text);
        rules::lint_file(&idx, &mut report);
        report.files_scanned += 1;
    }
    report.violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    report
}

/// Recursively collect `*.rs` files under `root` (sorted for stable
/// output) and lint them. Returns `Err` on I/O failures — the CLI maps
/// that to exit code 2, distinct from "violations found" (1).
pub fn lint_tree(root: &Path) -> Result<LintReport, String> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .map_err(|e| format!("cannot read {}: {e}", p.display()))?;
        files.push((p.to_string_lossy().replace('\\', "/"), text));
    }
    let borrowed: Vec<(&str, &str)> =
        files.iter().map(|(p, t)| (p.as_str(), t.as_str())).collect();
    Ok(lint_sources(&borrowed))
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("cannot read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("bad dir entry under {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_is_parseable() {
        let report = lint_sources(&[(
            "coordinator/batcher.rs",
            "fn f(v: &[u32]) -> u32 { v.first().copied().unwrap() }\n",
        )]);
        assert!(!report.clean());
        let parsed = crate::util::json::parse(&report.to_json()).expect("lint json must parse");
        let n = parsed.get("violation_count").and_then(|v| v.as_usize());
        assert_eq!(n, Some(report.violations.len()));
    }

    #[test]
    fn lint_tree_walks_this_crate() {
        // The crate's own source tree must be reachable and lint clean —
        // this is the same invariant CI enforces via `acore-cim lint`.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = lint_tree(&root).expect("src tree must be readable");
        assert!(report.files_scanned > 10);
        assert!(
            report.clean(),
            "lint violations in tree:\n{}",
            report
                .violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
