//! Runtime layer: PJRT execution of the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) from the rust hot path. Python is never
//! imported at runtime — `make artifacts` is the only compile-path step.

pub mod artifact;
pub mod executor;
pub mod signature;

pub use artifact::Manifest;
pub use executor::{Executor, TensorF32};
pub use signature::CimRuntime;
