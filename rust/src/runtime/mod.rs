//! Runtime layer: execution of the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) from the rust hot path. Python is never
//! imported at runtime — `make artifacts` is the only compile-path step.
//!
//! Two backends, selected at build time:
//! * **`pjrt` feature** — the xla-backed PJRT executor ([`executor`])
//!   compiles the HLO text once and runs it on the PJRT CPU client.
//!   Requires a local `xla_extension` install (see rust/Cargo.toml).
//! * **default** — the golden-model fallback: [`CimRuntime`] evaluates
//!   the identical transfer function through the folded analog model, so
//!   the serving stack (batcher, cluster, CLI) builds and runs offline
//!   with zero external dependencies.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod signature;

pub use artifact::Manifest;
#[cfg(feature = "pjrt")]
pub use executor::{Executor, TensorF32};
pub use signature::CimRuntime;

/// Runtime-layer error (anyhow is not vendored; see DESIGN.md §2).
#[derive(Debug, Clone)]
pub struct RtError(pub String);

impl std::fmt::Display for RtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

impl From<String> for RtError {
    fn from(s: String) -> Self {
        Self(s)
    }
}

impl From<&str> for RtError {
    fn from(s: &str) -> Self {
        Self(s.to_string())
    }
}

pub type RtResult<T> = Result<T, RtError>;

/// Build an [`RtError`] from format arguments (local stand-in for
/// `anyhow::anyhow!`).
#[macro_export]
macro_rules! rt_err {
    ($($fmt:tt)*) => {
        $crate::runtime::RtError(format!($($fmt)*))
    };
}
