//! Input-signature builders for the AOT artifacts — the positional operand
//! order mirrors the python signatures in `python/compile/aot.py` exactly.
//!
//! `CimRuntime` is the hardware-accelerated counterpart of
//! `analog::CimAnalogModel::forward_batch`: same die parameters, same trim
//! state, but the evaluation runs through the compiled JAX/Pallas kernel
//! on PJRT when built with the `pjrt` feature. The default (offline)
//! build uses the golden-model fallback backend — the identical transfer
//! function evaluated through the folded analog model — so the serving
//! stack works without `xla_extension`. The parity integration test
//! (`rust/tests/parity.rs`, pjrt-only) holds the two implementations to
//! <= 1 ADC code of each other.

#[cfg(feature = "pjrt")]
use super::executor::{Executor, TensorF32};
use super::RtResult;
use crate::analog::variation::VariationSample;
use crate::analog::{consts as c, samp, CimAnalogModel};
use crate::config::SimConfig;

/// Trim state fed to the artifact (mirrors the per-column 2SA registers).
#[derive(Debug, Clone)]
pub struct TrimState {
    pub pot_p: Vec<u32>,
    pub pot_n: Vec<u32>,
    pub cal: Vec<u32>,
}

impl TrimState {
    pub fn nominal() -> Self {
        Self {
            pot_p: vec![samp::rsa_to_pot(c::R_SA_NOM); c::M_COLS],
            pot_n: vec![samp::rsa_to_pot(c::R_SA_NOM); c::M_COLS],
            cal: vec![samp::vcal_to_cal(c::V_CAL_NOM); c::M_COLS],
        }
    }

    pub fn rsa_p(&self) -> Vec<f32> {
        self.pot_p.iter().map(|&p| samp::pot_to_rsa(p) as f32).collect()
    }

    pub fn rsa_n(&self) -> Vec<f32> {
        self.pot_n.iter().map(|&p| samp::pot_to_rsa(p) as f32).collect()
    }

    pub fn vcal(&self) -> Vec<f32> {
        self.cal.iter().map(|&p| samp::cal_to_vcal(p) as f32).collect()
    }
}

#[cfg(feature = "pjrt")]
fn f32s(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// The evaluation backend behind `CimRuntime`.
enum Backend {
    /// Golden-model fallback (default build): the folded analog fast path,
    /// noise-free, bit-faithful to the artifact math.
    Golden(Box<CimAnalogModel>),
    /// The compiled JAX/Pallas artifact on the PJRT CPU client.
    #[cfg(feature = "pjrt")]
    Pjrt(Executor),
}

/// The CIM array executed through the runtime backend.
pub struct CimRuntime {
    backend: Backend,
    sample: VariationSample,
    pub trims: TrimState,
    /// ADC references (v_l, v_h)
    pub adc_refs: (f64, f64),
    /// programmed signed weight codes, row-major N*M
    weights: Vec<i32>,
}

impl CimRuntime {
    /// PJRT-backed runtime (requires the `pjrt` feature + artifacts).
    #[cfg(feature = "pjrt")]
    pub fn new(exec: Executor, sample: VariationSample) -> Self {
        Self {
            backend: Backend::Pjrt(exec),
            sample,
            trims: TrimState::nominal(),
            adc_refs: (c::V_ADC_L, c::V_ADC_H),
            weights: vec![0; c::N_ROWS * c::M_COLS],
        }
    }

    /// Golden-model fallback backend: always available, no artifacts
    /// needed. Evaluates the same die (same `VariationSample`) through the
    /// folded analog fast path.
    pub fn golden(sample: VariationSample) -> Self {
        let cfg = SimConfig { sigma_noise: 0.0, ..SimConfig::default() };
        let model = CimAnalogModel::from_sample(&cfg, &sample);
        Self {
            backend: Backend::Golden(Box::new(model)),
            sample,
            trims: TrimState::nominal(),
            adc_refs: (c::V_ADC_L, c::V_ADC_H),
            weights: vec![0; c::N_ROWS * c::M_COLS],
        }
    }

    /// True when this runtime executes through PJRT (vs the fallback).
    pub fn is_pjrt(&self) -> bool {
        match &self.backend {
            Backend::Golden(_) => false,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => true,
        }
    }

    pub fn sample(&self) -> &VariationSample {
        &self.sample
    }

    pub fn weights(&self) -> &[i32] {
        &self.weights
    }

    #[cfg(feature = "pjrt")]
    pub fn executor(&self) -> Option<&Executor> {
        match &self.backend {
            Backend::Pjrt(exec) => Some(exec),
            _ => None,
        }
    }

    pub fn program(&mut self, weights: &[i32]) {
        assert_eq!(weights.len(), c::N_ROWS * c::M_COLS);
        for (dst, &w) in self.weights.iter_mut().zip(weights) {
            *dst = w.clamp(-c::CODE_MAX, c::CODE_MAX);
        }
        if let Backend::Golden(model) = &mut self.backend {
            model.program(&self.weights);
        }
    }

    /// Mirror the register state (trims + ADC references) into the golden
    /// model before an evaluation.
    fn sync_golden(model: &mut CimAnalogModel, trims: &TrimState, adc_refs: (f64, f64)) {
        for col in 0..c::M_COLS {
            model.set_trims(
                col,
                trims.pot_p[col].min(samp::POT_MAX),
                trims.pot_n[col].min(samp::POT_MAX),
                trims.cal[col].min(samp::CAL_MAX),
            );
        }
        model.set_adc_refs(adc_refs.0, adc_refs.1);
    }

    /// Batched forward. `x` is row-major `batch x N` signed codes; returns
    /// `batch x M` ADC codes. On the PJRT backend the batch is padded up
    /// to the nearest emitted artifact size.
    pub fn forward_batch(&mut self, x: &[i32], batch: usize) -> RtResult<Vec<u32>> {
        assert_eq!(x.len(), batch * c::N_ROWS);
        match &mut self.backend {
            Backend::Golden(model) => {
                Self::sync_golden(model, &self.trims, self.adc_refs);
                Ok(model.forward_batch(x, batch))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => self.forward_batch_pjrt(x, batch),
        }
    }

    /// `forward_batch` into a caller-owned output buffer. On the golden
    /// backend this is the zero-copy serving form (the register sync
    /// still refolds per call — that is the fallback's documented
    /// overhead); the PJRT backend routes through the allocating path,
    /// since the artifact owns its output tensors.
    pub fn forward_batch_into(
        &mut self,
        x: &[i32],
        batch: usize,
        out: &mut Vec<u32>,
    ) -> RtResult<()> {
        assert_eq!(x.len(), batch * c::N_ROWS);
        match &mut self.backend {
            Backend::Golden(model) => {
                Self::sync_golden(model, &self.trims, self.adc_refs);
                model.forward_batch_into(x, batch, out);
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => {
                let q = self.forward_batch_pjrt(x, batch)?;
                out.clear();
                out.extend_from_slice(&q);
                Ok(())
            }
        }
    }

    #[cfg(feature = "pjrt")]
    fn adc_consts(&self) -> TensorF32 {
        TensorF32::new(
            vec![
                self.sample.adc_alpha as f32,
                self.sample.adc_beta as f32,
                self.adc_refs.0 as f32,
                self.adc_refs.1 as f32,
                self.sample.kappa_in as f32,
                self.sample.kappa_reg as f32,
            ],
            &[6],
        )
    }

    /// Weight split fed to the artifact: magnitudes on the +/- lines.
    #[cfg(feature = "pjrt")]
    fn weight_split(&self) -> (Vec<f32>, Vec<f32>) {
        let mut w_pos = vec![0.0f32; c::N_ROWS * c::M_COLS];
        let mut w_neg = vec![0.0f32; c::N_ROWS * c::M_COLS];
        for (i, &w) in self.weights.iter().enumerate() {
            w_pos[i] = w.max(0) as f32;
            w_neg[i] = (-w).max(0) as f32;
        }
        (w_pos, w_neg)
    }

    /// Batched forward through the `cim_mac_b*` artifact.
    #[cfg(feature = "pjrt")]
    fn forward_batch_pjrt(&mut self, x: &[i32], batch: usize) -> RtResult<Vec<u32>> {
        let (name, padded) = {
            let Backend::Pjrt(exec) = &self.backend else {
                unreachable!("pjrt forward on non-pjrt backend")
            };
            let meta = exec
                .manifest()
                .cim_mac_for_batch(batch)
                .ok_or_else(|| crate::rt_err!("no cim_mac artifact fits batch {batch}"))?;
            (meta.name.clone(), super::artifact::Manifest::batch_of(meta))
        };
        let mut xf = vec![0f32; padded * c::N_ROWS];
        for (dst, &src) in xf.iter_mut().zip(x) {
            *dst = src as f32;
        }
        let (w_pos, w_neg) = self.weight_split();
        let s = &self.sample;
        let n = c::N_ROWS;
        let m = c::M_COLS;
        let inputs = vec![
            TensorF32::new(xf, &[padded, n]),
            TensorF32::new(w_pos, &[n, m]),
            TensorF32::new(w_neg, &[n, m]),
            TensorF32::new(f32s(&s.dac_gain), &[n]),
            TensorF32::new(f32s(&s.dac_off), &[n]),
            TensorF32::new(f32s(&s.cell_delta), &[n, m]),
            TensorF32::new(f32s(&s.alpha_p), &[m]),
            TensorF32::new(f32s(&s.alpha_n), &[m]),
            TensorF32::new(f32s(&s.beta), &[m]),
            TensorF32::new(f32s(&s.gamma3), &[m]),
            TensorF32::new(self.trims.rsa_p(), &[m]),
            TensorF32::new(self.trims.rsa_n(), &[m]),
            TensorF32::new(self.trims.vcal(), &[m]),
            self.adc_consts(),
            TensorF32::new(vec![0.0; padded * m], &[padded, m]),
        ];
        let Backend::Pjrt(exec) = &mut self.backend else {
            unreachable!("pjrt forward on non-pjrt backend")
        };
        let out = exec.run(&name, &inputs)?;
        Ok(out[..batch * m].iter().map(|&q| q as u32).collect())
    }

    /// Run the fused whole-network `mlp_cim_b*` artifact (PJRT only — the
    /// fallback path runs the tile scheduler on the analog model instead).
    #[cfg(feature = "pjrt")]
    #[allow(clippy::too_many_arguments)]
    pub fn mlp_forward(
        &mut self,
        name: &str,
        x_codes: &[f32],
        batch: usize,
        w1: (&[f32], &[f32]),
        b1: &[f32],
        w2: (&[f32], &[f32]),
        b2: &[f32],
        act_scale1: f32,
        vadc1: (f64, f64),
        vadc2: (f64, f64),
        trim1: (&[f32], &[f32]),
        trim2: (&[f32], &[f32]),
    ) -> RtResult<Vec<f32>> {
        let adc_consts = self.adc_consts();
        let rsa_p = self.trims.rsa_p();
        let rsa_n = self.trims.rsa_n();
        let vcal = self.trims.vcal();
        let s = &self.sample;
        let n = c::N_ROWS;
        let m = c::M_COLS;
        assert_eq!(x_codes.len(), batch * 22 * n);
        let inputs = vec![
            TensorF32::new(x_codes.to_vec(), &[batch, 22 * n]),
            TensorF32::new(w1.0.to_vec(), &[22, 3, n, m]),
            TensorF32::new(w1.1.to_vec(), &[22, 3, n, m]),
            TensorF32::new(b1.to_vec(), &[72]),
            TensorF32::new(w2.0.to_vec(), &[2, 1, n, m]),
            TensorF32::new(w2.1.to_vec(), &[2, 1, n, m]),
            TensorF32::new(b2.to_vec(), &[10]),
            TensorF32::scalar(act_scale1),
            TensorF32::new(f32s(&s.dac_gain), &[n]),
            TensorF32::new(f32s(&s.dac_off), &[n]),
            TensorF32::new(f32s(&s.cell_delta), &[n, m]),
            TensorF32::new(f32s(&s.alpha_p), &[m]),
            TensorF32::new(f32s(&s.alpha_n), &[m]),
            TensorF32::new(f32s(&s.beta), &[m]),
            TensorF32::new(f32s(&s.gamma3), &[m]),
            TensorF32::new(rsa_p, &[m]),
            TensorF32::new(rsa_n, &[m]),
            TensorF32::new(vcal, &[m]),
            adc_consts,
            TensorF32::new(vec![vadc1.0 as f32, vadc1.1 as f32], &[2]),
            TensorF32::new(vec![vadc2.0 as f32, vadc2.1 as f32], &[2]),
            TensorF32::new(trim1.0.to_vec(), &[m]),
            TensorF32::new(trim1.1.to_vec(), &[m]),
            TensorF32::new(trim2.0.to_vec(), &[m]),
            TensorF32::new(trim2.1.to_vec(), &[m]),
        ];
        let Backend::Pjrt(exec) = &mut self.backend else {
            return Err(crate::rt_err!("mlp_forward requires the PJRT backend"));
        };
        exec.run(name, &inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_backend_matches_analog_model() {
        let cfg = SimConfig { sigma_noise: 0.0, ..SimConfig::default() };
        let sample = VariationSample::draw(&cfg);
        let mut rt = CimRuntime::golden(sample.clone());
        let mut model = CimAnalogModel::from_sample(&cfg, &sample);
        let weights: Vec<i32> =
            (0..c::N_ROWS * c::M_COLS).map(|i| ((i as i32 * 13) % 127) - 63).collect();
        rt.program(&weights);
        model.program(&weights);
        let x: Vec<i32> = (0..4 * c::N_ROWS).map(|i| (i as i32 % 100) - 50).collect();
        // input codes outside the DAC range are clamped identically by
        // forward_batch on both sides (same code path), so compare raw
        let q_rt = rt.forward_batch(&x, 4).unwrap();
        let q_model = model.forward_batch(&x, 4);
        assert_eq!(q_rt, q_model);
        assert!(!rt.is_pjrt());
    }

    #[test]
    fn golden_backend_tracks_trims_and_refs() {
        let mut rt = CimRuntime::golden(VariationSample::ideal());
        let weights = vec![40i32; c::N_ROWS * c::M_COLS];
        rt.program(&weights);
        let x = vec![30i32; c::N_ROWS];
        let q0 = rt.forward_batch(&x, 1).unwrap();
        rt.trims.pot_p[0] = samp::POT_MAX;
        rt.trims.cal[0] = samp::CAL_MAX;
        let q1 = rt.forward_batch(&x, 1).unwrap();
        assert_ne!(q0[0], q1[0], "trims must reach the backend");
        assert_eq!(q0[1], q1[1], "other columns untouched");
        rt.adc_refs = (0.19, 0.63);
        let q2 = rt.forward_batch(&x, 1).unwrap();
        assert!(q2[1] < q1[1], "wider ADC range => smaller code");
    }
}
