//! Input-signature builders for the AOT artifacts — the positional operand
//! order mirrors the python signatures in `python/compile/aot.py` exactly.
//!
//! `CimRuntime` is the hardware-accelerated counterpart of
//! `analog::CimAnalogModel::forward_batch`: same die parameters, same trim
//! state, but the evaluation runs through the compiled JAX/Pallas kernel
//! on PJRT. The parity integration test (`rust/tests/parity.rs`) holds the
//! two implementations to <= 1 ADC code of each other.

use super::executor::{Executor, TensorF32};
use crate::analog::variation::VariationSample;
use crate::analog::{consts as c, samp};
use anyhow::{anyhow, Result};

/// Trim state fed to the artifact (mirrors the per-column 2SA registers).
#[derive(Debug, Clone)]
pub struct TrimState {
    pub pot_p: Vec<u32>,
    pub pot_n: Vec<u32>,
    pub cal: Vec<u32>,
}

impl TrimState {
    pub fn nominal() -> Self {
        Self {
            pot_p: vec![samp::rsa_to_pot(c::R_SA_NOM); c::M_COLS],
            pot_n: vec![samp::rsa_to_pot(c::R_SA_NOM); c::M_COLS],
            cal: vec![samp::vcal_to_cal(c::V_CAL_NOM); c::M_COLS],
        }
    }

    pub fn rsa_p(&self) -> Vec<f32> {
        self.pot_p.iter().map(|&p| samp::pot_to_rsa(p) as f32).collect()
    }

    pub fn rsa_n(&self) -> Vec<f32> {
        self.pot_n.iter().map(|&p| samp::pot_to_rsa(p) as f32).collect()
    }

    pub fn vcal(&self) -> Vec<f32> {
        self.cal.iter().map(|&p| samp::cal_to_vcal(p) as f32).collect()
    }
}

fn f32s(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

/// The CIM array executed through the PJRT artifact.
pub struct CimRuntime {
    exec: Executor,
    sample: VariationSample,
    pub trims: TrimState,
    /// ADC references (v_l, v_h)
    pub adc_refs: (f64, f64),
    /// weight split: magnitudes on the +/- lines, row-major N*M
    w_pos: Vec<f32>,
    w_neg: Vec<f32>,
}

impl CimRuntime {
    pub fn new(exec: Executor, sample: VariationSample) -> Self {
        Self {
            exec,
            sample,
            trims: TrimState::nominal(),
            adc_refs: (c::V_ADC_L, c::V_ADC_H),
            w_pos: vec![0.0; c::N_ROWS * c::M_COLS],
            w_neg: vec![0.0; c::N_ROWS * c::M_COLS],
        }
    }

    pub fn executor(&self) -> &Executor {
        &self.exec
    }

    pub fn program(&mut self, weights: &[i32]) {
        assert_eq!(weights.len(), c::N_ROWS * c::M_COLS);
        for (i, &w) in weights.iter().enumerate() {
            let w = w.clamp(-c::CODE_MAX, c::CODE_MAX);
            self.w_pos[i] = w.max(0) as f32;
            self.w_neg[i] = (-w).max(0) as f32;
        }
    }

    fn adc_consts(&self) -> TensorF32 {
        TensorF32::new(
            vec![
                self.sample.adc_alpha as f32,
                self.sample.adc_beta as f32,
                self.adc_refs.0 as f32,
                self.adc_refs.1 as f32,
                self.sample.kappa_in as f32,
                self.sample.kappa_reg as f32,
            ],
            &[6],
        )
    }

    /// Batched forward through the `cim_mac_b*` artifact. `x` is row-major
    /// `batch x N` signed codes; returns `batch x M` ADC codes. The batch
    /// is padded up to the nearest emitted artifact size.
    pub fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>> {
        assert_eq!(x.len(), batch * c::N_ROWS);
        let meta = self
            .exec
            .manifest()
            .cim_mac_for_batch(batch)
            .ok_or_else(|| anyhow!("no cim_mac artifact fits batch {batch}"))?;
        let padded = super::artifact::Manifest::batch_of(meta);
        let name = meta.name.clone();
        let mut xf = vec![0f32; padded * c::N_ROWS];
        for (dst, &src) in xf.iter_mut().zip(x) {
            *dst = src as f32;
        }
        let s = &self.sample;
        let n = c::N_ROWS;
        let m = c::M_COLS;
        let inputs = vec![
            TensorF32::new(xf, &[padded, n]),
            TensorF32::new(self.w_pos.clone(), &[n, m]),
            TensorF32::new(self.w_neg.clone(), &[n, m]),
            TensorF32::new(f32s(&s.dac_gain), &[n]),
            TensorF32::new(f32s(&s.dac_off), &[n]),
            TensorF32::new(f32s(&s.cell_delta), &[n, m]),
            TensorF32::new(f32s(&s.alpha_p), &[m]),
            TensorF32::new(f32s(&s.alpha_n), &[m]),
            TensorF32::new(f32s(&s.beta), &[m]),
            TensorF32::new(f32s(&s.gamma3), &[m]),
            TensorF32::new(self.trims.rsa_p(), &[m]),
            TensorF32::new(self.trims.rsa_n(), &[m]),
            TensorF32::new(self.trims.vcal(), &[m]),
            self.adc_consts(),
            TensorF32::new(vec![0.0; padded * m], &[padded, m]),
        ];
        let out = self.exec.run(&name, &inputs)?;
        Ok(out[..batch * m].iter().map(|&q| q as u32).collect())
    }

    /// Run the fused whole-network `mlp_cim_b*` artifact.
    #[allow(clippy::too_many_arguments)]
    pub fn mlp_forward(
        &mut self,
        name: &str,
        x_codes: &[f32],
        batch: usize,
        w1: (&[f32], &[f32]),
        b1: &[f32],
        w2: (&[f32], &[f32]),
        b2: &[f32],
        act_scale1: f32,
        vadc1: (f64, f64),
        vadc2: (f64, f64),
        trim1: (&[f32], &[f32]),
        trim2: (&[f32], &[f32]),
    ) -> Result<Vec<f32>> {
        let s = &self.sample;
        let n = c::N_ROWS;
        let m = c::M_COLS;
        assert_eq!(x_codes.len(), batch * 22 * n);
        let inputs = vec![
            TensorF32::new(x_codes.to_vec(), &[batch, 22 * n]),
            TensorF32::new(w1.0.to_vec(), &[22, 3, n, m]),
            TensorF32::new(w1.1.to_vec(), &[22, 3, n, m]),
            TensorF32::new(b1.to_vec(), &[72]),
            TensorF32::new(w2.0.to_vec(), &[2, 1, n, m]),
            TensorF32::new(w2.1.to_vec(), &[2, 1, n, m]),
            TensorF32::new(b2.to_vec(), &[10]),
            TensorF32::scalar(act_scale1),
            TensorF32::new(f32s(&s.dac_gain), &[n]),
            TensorF32::new(f32s(&s.dac_off), &[n]),
            TensorF32::new(f32s(&s.cell_delta), &[n, m]),
            TensorF32::new(f32s(&s.alpha_p), &[m]),
            TensorF32::new(f32s(&s.alpha_n), &[m]),
            TensorF32::new(f32s(&s.beta), &[m]),
            TensorF32::new(f32s(&s.gamma3), &[m]),
            TensorF32::new(self.trims.rsa_p(), &[m]),
            TensorF32::new(self.trims.rsa_n(), &[m]),
            TensorF32::new(self.trims.vcal(), &[m]),
            self.adc_consts(),
            TensorF32::new(vec![vadc1.0 as f32, vadc1.1 as f32], &[2]),
            TensorF32::new(vec![vadc2.0 as f32, vadc2.1 as f32], &[2]),
            TensorF32::new(trim1.0.to_vec(), &[m]),
            TensorF32::new(trim1.1.to_vec(), &[m]),
            TensorF32::new(trim2.0.to_vec(), &[m]),
            TensorF32::new(trim2.1.to_vec(), &[m]),
        ];
        self.exec.run(name, &inputs)
    }
}
