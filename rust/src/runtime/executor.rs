//! PJRT executor: loads the HLO-text artifacts and runs them on the PJRT
//! CPU client (the `xla` crate wraps xla_extension's PJRT C API). One
//! compiled executable per artifact, cached — compile once, execute on the
//! hot path. Only built with the `pjrt` feature (the crate has no
//! vendored deps; see rust/Cargo.toml for how to supply `xla`).
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use super::artifact::{ArtifactMeta, Manifest};
use super::{RtError, RtResult};
use crate::rt_err;
use std::collections::HashMap;

/// A host tensor handed to / returned from an executable.
#[derive(Debug, Clone)]
pub struct TensorF32 {
    pub data: Vec<f32>,
    pub shape: Vec<i64>,
}

impl TensorF32 {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "shape/data mismatch");
        Self { data, shape: shape.iter().map(|&d| d as i64).collect() }
    }

    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], shape: vec![] }
    }

    fn to_literal(&self) -> RtResult<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let shaped = if self.shape.is_empty() {
            // rank-0: reshape to scalar
            lit.reshape(&[])
        } else {
            lit.reshape(&self.shape)
        };
        shaped.map_err(|e| rt_err!("reshaping literal: {e:?}"))
    }
}

pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions performed (for perf accounting)
    pub executions: u64,
}

impl Executor {
    pub fn new(manifest: Manifest) -> RtResult<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| rt_err!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self { client, manifest, cache: HashMap::new(), executions: 0 })
    }

    pub fn discover() -> RtResult<Self> {
        let manifest = Manifest::discover().map_err(RtError)?;
        Self::new(manifest)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn meta(&self, name: &str) -> RtResult<ArtifactMeta> {
        self.manifest
            .find(name)
            .cloned()
            .ok_or_else(|| rt_err!("artifact `{name}` not in manifest"))
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn prepare(&mut self, name: &str) -> RtResult<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.meta(name)?;
        let path = meta
            .path
            .to_str()
            .ok_or_else(|| rt_err!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| rt_err!("parsing {}: {e:?}", meta.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| rt_err!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact; returns the first element of the result tuple
    /// as a flat f32 vector (aot.py lowers with return_tuple=True).
    pub fn run(&mut self, name: &str, inputs: &[TensorF32]) -> RtResult<Vec<f32>> {
        let meta = self.meta(name)?;
        if inputs.len() != meta.num_inputs {
            return Err(rt_err!(
                "artifact `{name}` expects {} inputs, got {}",
                meta.num_inputs,
                inputs.len()
            ));
        }
        // shape check against the manifest
        for (i, (t, want)) in inputs.iter().zip(&meta.input_shapes).enumerate() {
            let got: Vec<usize> = t.shape.iter().map(|&d| d as usize).collect();
            if &got != want {
                return Err(rt_err!(
                    "artifact `{name}` input {i}: shape {got:?}, manifest says {want:?}"
                ));
            }
        }
        self.prepare(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<RtResult<_>>()?;
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| rt_err!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err!("fetching {name} result: {e:?}"))?;
        self.executions += 1;
        let out = result
            .to_tuple1()
            .map_err(|e| rt_err!("untupling {name} result: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| rt_err!("reading {name} result: {e:?}"))
    }
}
