//! PJRT executor: loads the HLO-text artifacts and runs them on the PJRT
//! CPU client (the `xla` crate wraps xla_extension's PJRT C API). One
//! compiled executable per artifact, cached — compile once, execute on the
//! hot path.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use super::artifact::{ArtifactMeta, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;

/// A host tensor handed to / returned from an executable.
#[derive(Debug, Clone)]
pub struct TensorF32 {
    pub data: Vec<f32>,
    pub shape: Vec<i64>,
}

impl TensorF32 {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "shape/data mismatch");
        Self { data, shape: shape.iter().map(|&d| d as i64).collect() }
    }

    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], shape: vec![] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&self.shape)?)
        }
    }
}

pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions performed (for perf accounting)
    pub executions: u64,
}

impl Executor {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, cache: HashMap::new(), executions: 0 })
    }

    pub fn discover() -> Result<Self> {
        let manifest = Manifest::discover().map_err(|e| anyhow!(e))?;
        Self::new(manifest)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        self.manifest
            .find(name)
            .cloned()
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.meta(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            meta.path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", meta.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact; returns the first element of the result tuple
    /// as a flat f32 vector (aot.py lowers with return_tuple=True).
    pub fn run(&mut self, name: &str, inputs: &[TensorF32]) -> Result<Vec<f32>> {
        let meta = self.meta(name)?;
        if inputs.len() != meta.num_inputs {
            return Err(anyhow!(
                "artifact `{name}` expects {} inputs, got {}",
                meta.num_inputs,
                inputs.len()
            ));
        }
        // shape check against the manifest
        for (i, (t, want)) in inputs.iter().zip(&meta.input_shapes).enumerate() {
            let got: Vec<usize> = t.shape.iter().map(|&d| d as usize).collect();
            if &got != want {
                return Err(anyhow!(
                    "artifact `{name}` input {i}: shape {got:?}, manifest says {want:?}"
                ));
            }
        }
        self.prepare(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        self.executions += 1;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}
