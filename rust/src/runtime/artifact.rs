//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and locates the HLO-text files the PJRT
//! executor loads. Python never runs at inference time — these files are
//! the entire L2/L1 hand-off.

use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub num_inputs: usize,
    pub input_shapes: Vec<Vec<usize>>,
    pub sha256: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load from a directory containing manifest.json + *.hlo.txt.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("cannot read manifest in {}: {e} (run `make artifacts`)", dir.display()))?;
        let j = json::parse(&text).map_err(|e| e.to_string())?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest missing `artifacts`")?;
        let mut artifacts = Vec::new();
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or("artifact missing name")?
                .to_string();
            let rel = a
                .get("path")
                .and_then(Json::as_str)
                .ok_or("artifact missing path")?;
            let num_inputs = a
                .get("num_inputs")
                .and_then(Json::as_usize)
                .ok_or("artifact missing num_inputs")?;
            let input_shapes = a
                .get("input_shapes")
                .and_then(Json::as_arr)
                .map(|shapes| {
                    shapes
                        .iter()
                        .map(|s| {
                            s.as_arr()
                                .unwrap_or(&[])
                                .iter()
                                .filter_map(Json::as_usize)
                                .collect()
                        })
                        .collect()
                })
                .unwrap_or_default();
            let sha256 = a
                .get("sha256")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            artifacts.push(ArtifactMeta {
                name,
                path: dir.join(rel),
                num_inputs,
                input_shapes,
                sha256,
            });
        }
        Ok(Self { artifacts, dir: dir.to_path_buf() })
    }

    /// Default search: $ACORE_ARTIFACTS, ./artifacts, ../artifacts.
    pub fn discover() -> Result<Self, String> {
        let candidates = [
            std::env::var("ACORE_ARTIFACTS").ok().map(PathBuf::from),
            Some(PathBuf::from("artifacts")),
            Some(PathBuf::from("../artifacts")),
        ];
        for dir in candidates.into_iter().flatten() {
            if dir.join("manifest.json").exists() {
                return Self::load(&dir);
            }
        }
        Err("no artifacts directory found; run `make artifacts`".to_string())
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest cim_mac artifact whose batch is >= `batch` (shape-
    /// specialized HLO requires padding up to the next emitted size).
    pub fn cim_mac_for_batch(&self, batch: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.name.starts_with("cim_mac_b"))
            .filter_map(|a| {
                a.name
                    .trim_start_matches("cim_mac_b")
                    .parse::<usize>()
                    .ok()
                    .map(|b| (b, a))
            })
            .filter(|(b, _)| *b >= batch)
            .min_by_key(|(b, _)| *b)
            .map(|(_, a)| a)
    }

    pub fn batch_of(meta: &ArtifactMeta) -> usize {
        meta.input_shapes.first().map(|s| s[0]).unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        for dir in ["artifacts", "../artifacts"] {
            let p = Path::new(dir);
            if p.join("manifest.json").exists() {
                return Some(Manifest::load(p).unwrap());
            }
        }
        None
    }

    #[test]
    fn loads_repo_manifest() {
        // artifacts are a build-time product of `make artifacts` (needs
        // jax); skip rather than fail on an offline checkout
        let Some(m) = repo_artifacts() else {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            return;
        };
        assert!(m.find("cim_mac_b1").is_some());
        let b1 = m.find("cim_mac_b1").unwrap();
        assert_eq!(b1.num_inputs, 15);
        assert_eq!(b1.input_shapes[0], vec![1, 36]);
        assert!(b1.path.exists());
    }

    #[test]
    fn batch_selection_picks_smallest_fit() {
        let Some(m) = repo_artifacts() else {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            return;
        };
        assert_eq!(m.cim_mac_for_batch(1).unwrap().name, "cim_mac_b1");
        assert_eq!(m.cim_mac_for_batch(2).unwrap().name, "cim_mac_b8");
        assert_eq!(m.cim_mac_for_batch(100).unwrap().name, "cim_mac_b128");
        assert_eq!(m.cim_mac_for_batch(1024).unwrap().name, "cim_mac_b1024");
        assert!(m.cim_mac_for_batch(100_000).is_none());
    }

    #[test]
    fn synthetic_manifest_batch_selection() {
        // exercise the selection logic without on-disk artifacts
        let meta = |name: &str, b: usize| ArtifactMeta {
            name: name.to_string(),
            path: PathBuf::from(format!("{name}.hlo.txt")),
            num_inputs: 15,
            input_shapes: vec![vec![b, 36]],
            sha256: String::new(),
        };
        let m = Manifest {
            artifacts: vec![meta("cim_mac_b1", 1), meta("cim_mac_b8", 8), meta("other", 4)],
            dir: PathBuf::from("."),
        };
        assert_eq!(m.cim_mac_for_batch(1).unwrap().name, "cim_mac_b1");
        assert_eq!(m.cim_mac_for_batch(5).unwrap().name, "cim_mac_b8");
        assert!(m.cim_mac_for_batch(9).is_none());
        assert_eq!(Manifest::batch_of(m.find("cim_mac_b8").unwrap()), 8);
    }
}
