//! # acore-cim
//!
//! Full-system simulation reproduction of *Acore-CIM: build accurate and
//! reliable mixed-signal CIM cores with RISC-V controlled self-calibration*
//! (CS.AR 2025) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the SoC: a circuit-level analog model of the
//!   36x32 MDAC-weight-cell CIM core ([`analog`]), a RISC-V RV32IM
//!   instruction-set simulator with an AXI4-Lite interconnect ([`soc`]),
//!   the Built-In Self-Calibration engine, DNN tile scheduler, compute
//!   SNR evaluation, the multi-core sharded serving cluster, and its TCP
//!   wire front-end ([`coordinator`]), dataset + MLP training utilities
//!   ([`data`]), and
//!   a runtime that executes the AOT-compiled JAX/Pallas artifacts on
//!   the hot path ([`runtime`]) — through PJRT with the `pjrt` feature,
//!   or the bit-faithful golden-model fallback by default.
//! * **L2/L1 (python/, build-time only)** — the JAX model of the same
//!   analog transfer function and the Pallas MAC kernel, lowered once to
//!   HLO text (`make artifacts`) and never imported at runtime.
//!
//! See DESIGN.md for the paper -> module map and EXPERIMENTS.md for the
//! reproduced tables/figures.

pub mod analog;
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod soc;
pub mod util;
