#!/usr/bin/env bash
# Refresh the committed bench/BENCH_perf_hotpath.{before,after}.json
# baselines with MEASURED numbers from this machine.
#
# The committed files are estimated (operation-count analysis — see their
# "provenance" field): the container that authored them had no Rust
# toolchain. This script replaces them honestly: it benches HEAD for the
# "after" file and a base commit (default: the merge-base with origin's
# default branch, falling back to HEAD^) in a detached worktree for the
# "before" file, both on THIS machine so the pair is comparable.
#
# Usage:
#   tools/refresh_bench_baselines.sh [BASE_COMMIT]
#
# Requires: cargo (stable), git. Runs with ACORE_BENCH_FAST=1 by default;
# export ACORE_BENCH_FAST=0 for full-length runs before committing.
#
# CI's bench-smoke job performs the same measurement every run and
# uploads it as the `bench-baseline-refresh` artifact — downloading that
# artifact and copying it over bench/ is the no-local-toolchain path.

set -euo pipefail

REPO_ROOT=$(git rev-parse --show-toplevel)
cd "$REPO_ROOT"

command -v cargo >/dev/null 2>&1 || {
  echo "error: cargo not found — run this on a machine with the Rust toolchain," >&2
  echo "or download CI's bench-baseline-refresh artifact instead." >&2
  exit 1
}

export ACORE_BENCH_FAST="${ACORE_BENCH_FAST:-1}"

BASE="${1:-}"
if [ -z "$BASE" ]; then
  DEFAULT_BRANCH=$(git symbolic-ref --quiet refs/remotes/origin/HEAD 2>/dev/null \
    | sed 's@^refs/remotes/@@' || true)
  if [ -n "$DEFAULT_BRANCH" ]; then
    BASE=$(git merge-base "$DEFAULT_BRANCH" HEAD)
  else
    BASE=$(git rev-parse 'HEAD^' 2>/dev/null || true)
  fi
fi
if [ -z "$BASE" ] || [ "$BASE" = "$(git rev-parse HEAD)" ]; then
  echo "error: no distinct base commit to measure 'before' against" >&2
  echo "       (pass one explicitly: tools/refresh_bench_baselines.sh <commit>)" >&2
  exit 1
fi

echo "after  = HEAD  $(git log -1 --oneline HEAD)"
echo "before = BASE  $(git log -1 --oneline "$BASE")"

OUT_AFTER=$(mktemp -d)
OUT_BEFORE=$(mktemp -d)
WORKTREE=$(mktemp -d -u)
cleanup() {
  git worktree remove --force "$WORKTREE" 2>/dev/null || true
  rm -rf "$OUT_AFTER" "$OUT_BEFORE"
}
trap cleanup EXIT

echo "== benching HEAD =="
ACORE_BENCH_JSON_DIR="$OUT_AFTER" cargo bench --bench perf_hotpath
test -f "$OUT_AFTER/BENCH_perf_hotpath.json"

echo "== benching base in a worktree =="
git worktree add --detach "$WORKTREE" "$BASE"
if ACORE_BENCH_JSON_DIR="$OUT_BEFORE" cargo bench --bench perf_hotpath \
     --manifest-path "$WORKTREE/rust/Cargo.toml" \
     --target-dir "$WORKTREE/target"; then
  test -f "$OUT_BEFORE/BENCH_perf_hotpath.json"
  cp "$OUT_BEFORE/BENCH_perf_hotpath.json" bench/BENCH_perf_hotpath.before.json
else
  echo "warning: base commit's bench does not build/run — leaving" >&2
  echo "         bench/BENCH_perf_hotpath.before.json untouched" >&2
fi

cp "$OUT_AFTER/BENCH_perf_hotpath.json" bench/BENCH_perf_hotpath.after.json

echo "== refreshed =="
ls -l bench/BENCH_perf_hotpath.before.json bench/BENCH_perf_hotpath.after.json
echo "review the diff, then commit the refreshed baselines."
