"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps batch sizes, code patterns, and variation magnitudes; the
folded kernel must agree with the explicit per-cell reference to float32
tolerance *before* quantization and exactly (codes) after, away from
rounding boundaries.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import params as P
from compile.kernels import cim_mac as K
from compile.kernels import ref
from tests.util import args_list, rand_inputs, rand_params, rand_weights


def run_both(x, w_pos, w_neg, p, tb=8):
    from compile import model
    q_kernel = np.asarray(model.cim_apply(*args_list(x, w_pos, w_neg, p), tb=tb))
    q_ref, v_sa = ref.cim_forward(*args_list(x, w_pos, w_neg, p))
    return q_kernel, np.asarray(q_ref), np.asarray(v_sa)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 33),
    seed=st.integers(0, 2**31 - 1),
    sigma=st.floats(0.0, 2.0),
    density=st.floats(0.1, 1.0),
)
def test_kernel_matches_ref(batch, seed, sigma, density):
    rng = np.random.default_rng(seed)
    _, w_pos, w_neg = rand_weights(rng, density)
    p = rand_params(rng, batch, sigma_scale=sigma)
    x = rand_inputs(rng, batch)
    q_kernel, q_ref, _ = run_both(x, w_pos, w_neg, p)
    assert q_kernel.shape == (batch, P.M_COLS)
    # Rounding at exactly .5 can differ between the two evaluation orders by
    # one code; everything else must match exactly.
    assert np.max(np.abs(q_kernel - q_ref)) <= 1.0
    assert np.mean(q_kernel != q_ref) < 0.02


def test_ideal_params_give_nominal_transfer():
    """With error-free parameters the array must realize Eq. (7) exactly."""
    from compile import model
    rng = np.random.default_rng(0)
    w, w_pos, w_neg = rand_weights(rng, 1.0)
    batch = 16
    x = rand_inputs(rng, batch)
    p = {k: np.asarray(v) for k, v in model.ideal_params(batch).items()}
    q_kernel, q_ref, _ = run_both(x, w_pos, w_neg, p)
    q_nom = np.asarray(ref.q_nominal(x, w))
    expected = np.clip(np.round(q_nom), 0, P.ADC_MAX)
    np.testing.assert_allclose(q_kernel, expected, atol=1.0)
    # Almost all codes identical (only .5-boundary ties may differ).
    assert np.mean(q_kernel != expected) < 0.01


def test_zero_input_zero_weight():
    from compile import model
    batch = 4
    p = {k: np.asarray(v) for k, v in model.ideal_params(batch).items()}
    x = np.zeros((batch, P.N_ROWS), np.float32)
    z = np.zeros((P.N_ROWS, P.M_COLS), np.float32)
    q, _, _ = run_both(x, z, z, p)
    # Zero MAC maps to the mid-code (V_CAL = V_BIAS -> code ~31.5 -> 32 or 31)
    assert np.all((q >= 31) & (q <= 32))


def test_full_scale_reaches_near_rails():
    """Full-scale MAC uses (almost) the whole ADC range: the design maps
    S_max = N*63*63 to ~code 62 (31.5 + 30.5), symmetric about mid-code."""
    from compile import model
    batch = 2
    p = {k: np.asarray(v) for k, v in model.ideal_params(batch).items()}
    w_pos = np.full((P.N_ROWS, P.M_COLS), P.CODE_MAX, np.float32)
    w_neg = np.zeros_like(w_pos)
    x = np.full((batch, P.N_ROWS), P.CODE_MAX, np.float32)
    q, _, _ = run_both(x, w_pos, w_neg, p)
    assert np.all(q == 62.0)
    q2, _, _ = run_both(-x, w_pos, w_neg, p)
    assert np.all(q2 == 1.0)


def test_clipping_saturates_at_rails():
    """A large ADC offset error must drive codes into hard clipping —
    the scenario BISC's reference-widening (Alg. 1) exists to avoid."""
    from compile import model
    batch = 2
    p = {k: np.asarray(v) for k, v in model.ideal_params(batch).items()}
    w_pos = np.full((P.N_ROWS, P.M_COLS), P.CODE_MAX, np.float32)
    w_neg = np.zeros_like(w_pos)
    x = np.full((batch, P.N_ROWS), P.CODE_MAX, np.float32)
    p = dict(p)
    p["adc_consts"] = np.array(
        [1.0, 40.0, P.V_ADC_L, P.V_ADC_H, 0.0, 0.0], np.float32)
    q, _, _ = run_both(x, w_pos, w_neg, p)
    assert np.all(q == P.ADC_MAX)
    p["adc_consts"] = np.array(
        [1.0, -40.0, P.V_ADC_L, P.V_ADC_H, 0.0, 0.0], np.float32)
    q2, _, _ = run_both(-x, w_pos, w_neg, p)
    assert np.all(q2 == 0.0)


def test_sign_symmetry():
    """x -> -x mirrors the output around the mid code (ideal params)."""
    from compile import model
    rng = np.random.default_rng(7)
    _, w_pos, w_neg = rand_weights(rng)
    batch = 8
    p = {k: np.asarray(v) for k, v in model.ideal_params(batch).items()}
    x = rand_inputs(rng, batch)
    qp, _, vp = run_both(x, w_pos, w_neg, p)
    qn, _, vn = run_both(-x, w_pos, w_neg, p)
    np.testing.assert_allclose(vp - P.V_CAL_NOM, -(vn - P.V_CAL_NOM),
                               atol=1e-6)


def test_noise_moves_output():
    from compile import model
    rng = np.random.default_rng(3)
    _, w_pos, w_neg = rand_weights(rng)
    batch = 4
    p = {k: np.asarray(v) for k, v in model.ideal_params(batch).items()}
    x = rand_inputs(rng, batch)
    q0, _, _ = run_both(x, w_pos, w_neg, p)
    p2 = dict(p)
    p2["noise_v"] = np.full((batch, P.M_COLS), 0.05, np.float32)  # ~8 LSB
    q1, _, _ = run_both(x, w_pos, w_neg, p2)
    assert np.mean(q1 - q0) > 5.0


@pytest.mark.parametrize("tb", [4, 8, 16, 128])
def test_tile_size_invariance(tb):
    """The batch tiling is a schedule, not a semantic: any TB same result."""
    from compile import model
    rng = np.random.default_rng(11)
    _, w_pos, w_neg = rand_weights(rng)
    batch = 19
    p = rand_params(rng, batch)
    x = rand_inputs(rng, batch)
    q_ref, _, _ = run_both(x, w_pos, w_neg, p, tb=1)
    q_tb, _, _ = run_both(x, w_pos, w_neg, p, tb=tb)
    np.testing.assert_array_equal(q_ref, q_tb)
