"""Shared helpers for the python test-suite: random physical-parameter
bundles matching the paper's variation magnitudes (Fig. 8b: g in ~0.8-1.2,
eps up to a few LSB)."""

import numpy as np

from compile import params as P


def rand_params(rng, batch, *, sigma_scale=1.0):
    n, m = P.N_ROWS, P.M_COLS
    f = np.float32
    return dict(
        dac_gain=(1.0 + 0.01 * sigma_scale * rng.standard_normal(n)).astype(f),
        dac_off=(0.002 * sigma_scale * rng.standard_normal(n)).astype(f),
        cell_delta=(0.02 * sigma_scale * rng.standard_normal((n, m))).astype(f),
        alpha_p=(1.0 + 0.08 * sigma_scale * rng.standard_normal(m)).astype(f),
        alpha_n=(1.0 + 0.08 * sigma_scale * rng.standard_normal(m)).astype(f),
        beta=(0.01 * sigma_scale * rng.standard_normal(m)).astype(f),
        gamma3=(3.0 * sigma_scale * rng.standard_normal(m)).astype(f),
        rsa_p=np.full(m, P.R_SA_NOM, f),
        rsa_n=np.full(m, P.R_SA_NOM, f),
        vcal=np.full(m, P.V_CAL_NOM, f),
        adc_consts=np.array(
            [1.0 + 0.02 * sigma_scale * rng.standard_normal(),
             0.5 * sigma_scale * rng.standard_normal(),
             P.V_ADC_L, P.V_ADC_H,
             P.KAPPA_IN_DEFAULT * sigma_scale,
             P.KAPPA_REG_DEFAULT * sigma_scale], f),
        noise_v=np.zeros((batch, m), f),
    )


def rand_weights(rng, density=0.9):
    """Signed weight codes split into +/- line magnitudes."""
    n, m = P.N_ROWS, P.M_COLS
    w = rng.integers(-P.CODE_MAX, P.CODE_MAX + 1, size=(n, m))
    w *= (rng.random((n, m)) < density)
    w_pos = np.maximum(w, 0).astype(np.float32)
    w_neg = np.maximum(-w, 0).astype(np.float32)
    return w.astype(np.float32), w_pos, w_neg


def rand_inputs(rng, batch, signed=True):
    lo = -P.CODE_MAX if signed else 0
    return rng.integers(lo, P.CODE_MAX + 1,
                        size=(batch, P.N_ROWS)).astype(np.float32)


def args_list(x, w_pos, w_neg, p):
    return [x, w_pos, w_neg, p["dac_gain"], p["dac_off"], p["cell_delta"],
            p["alpha_p"], p["alpha_n"], p["beta"], p["gamma3"], p["rsa_p"],
            p["rsa_n"], p["vcal"], p["adc_consts"], p["noise_v"]]
