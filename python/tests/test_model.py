"""L2 model tests: parameter folding, tiling helpers, and the MLP-on-CIM
graph (shape correctness + ideal-parameter accuracy sanity)."""

import numpy as np
import pytest

from compile import model, params as P
from compile.kernels import ref
from tests.util import rand_params, rand_weights


def test_fold_matches_ref_voltages():
    """Folded (g, qa, qb, qc) must reproduce the reference V_SA chain."""
    rng = np.random.default_rng(42)
    _, w_pos, w_neg = rand_weights(rng)
    p = rand_params(rng, 4)
    x = rng.integers(-63, 64, size=(4, P.N_ROWS)).astype(np.float32)
    g_pos, g_neg, qa, qb, qc, qd, qm = (np.asarray(a) for a in model.fold_params(
        w_pos, w_neg, p["dac_gain"], p["dac_off"], p["cell_delta"],
        p["alpha_p"], p["alpha_n"], p["beta"], p["gamma3"], p["rsa_p"],
        p["rsa_n"], p["vcal"], p["adc_consts"]))
    x_eff = np.asarray(model.fold_inputs(x, p["dac_gain"], p["dac_off"]))
    q_lin = (x_eff @ g_pos) * qa - (x_eff @ g_neg) * qb + qc
    q_folded = q_lin + qd * (q_lin - qm) ** 3
    _, v_sa = ref.cim_forward(
        x, w_pos, w_neg, p["dac_gain"], p["dac_off"], p["cell_delta"],
        p["alpha_p"], p["alpha_n"], p["beta"], p["gamma3"], p["rsa_p"],
        p["rsa_n"], p["vcal"], p["adc_consts"], p["noise_v"])
    c = p["adc_consts"]
    c_adc = P.ADC_MAX / (c[3] - c[2])
    q_ref = c[0] * c_adc * (np.asarray(v_sa) - c[2]) + c[1]
    np.testing.assert_allclose(q_folded, q_ref, rtol=1e-4, atol=1e-3)


def test_tile_counts():
    assert model.tile_counts(784, 72) == (22, 3)
    assert model.tile_counts(72, 10) == (2, 1)
    assert model.tile_counts(36, 32) == (1, 1)
    assert model.tile_counts(37, 33) == (2, 2)


def _tiled_weights(w, rt, ct):
    """Pack a dense [rows, cols] signed code matrix into [rt, ct, N, M]."""
    rows, cols = w.shape
    wp = np.zeros((rt, ct, P.N_ROWS, P.M_COLS), np.float32)
    wn = np.zeros_like(wp)
    padded = np.zeros((rt * P.N_ROWS, ct * P.M_COLS), np.float32)
    padded[:rows, :cols] = w
    for r in range(rt):
        for c in range(ct):
            blk = padded[r * P.N_ROWS:(r + 1) * P.N_ROWS,
                         c * P.M_COLS:(c + 1) * P.M_COLS]
            wp[r, c] = np.maximum(blk, 0)
            wn[r, c] = np.maximum(-blk, 0)
    return wp, wn


def _default_refs_trims():
    """Default ADC windows + disabled digital trims for mlp_cim."""
    m = P.M_COLS
    return (
        np.array([P.V_ADC_L, P.V_ADC_H], np.float32),
        np.array([P.V_ADC_L, P.V_ADC_H], np.float32),
        np.ones(m, np.float32), np.zeros(m, np.float32),
        np.ones(m, np.float32), np.zeros(m, np.float32),
    )


def _nominal_tiled_layer(x, w, cols):
    """Exact digital reference of the nominal tiled pipeline: per row-tile,
    the 6-bit ADC quantizes the partial MAC, the RISC-V side dequantizes
    with the nominal constants and accumulates (model._layer_on_cim with
    ideal analog parameters)."""
    k = ref.code_gain_nominal()
    mid = ref.q_mid_nominal()
    rt, ct = model.tile_counts(x.shape[1], cols)
    xp = np.zeros((x.shape[0], rt * P.N_ROWS), np.float32)
    xp[:, :x.shape[1]] = x
    wp = np.zeros((rt * P.N_ROWS, ct * P.M_COLS), np.float32)
    wp[:w.shape[0], :w.shape[1]] = w
    out = np.zeros((x.shape[0], ct * P.M_COLS), np.float32)
    for r in range(rt):
        s = xp[:, r * P.N_ROWS:(r + 1) * P.N_ROWS] @ \
            wp[r * P.N_ROWS:(r + 1) * P.N_ROWS]
        q = np.clip(np.round(mid + k * s), 0, P.ADC_MAX)
        out += (q - mid) / k
    return out[:, :cols]


def test_mlp_ideal_params_matches_nominal_tiled_reference():
    """With error-free physical params, the CIM MLP must equal the exact
    per-tile quantized digital reference — the 'simulation' baseline of
    Section VII-C (which already includes the 6-bit ADC quantization)."""
    rng = np.random.default_rng(0)
    batch = 8
    w1 = rng.integers(-15, 16, size=(784, 72)).astype(np.float32)
    w2 = rng.integers(-40, 41, size=(72, 10)).astype(np.float32)
    b1 = np.zeros(72, np.float32)
    b2 = np.zeros(10, np.float32)
    x = rng.integers(0, 20, size=(batch, 784)).astype(np.float32)
    x_pad = np.pad(x, ((0, 0), (0, 22 * P.N_ROWS - 784)))

    w1p, w1n = _tiled_weights(w1, 22, 3)
    w2p, w2n = _tiled_weights(w2, 2, 1)
    analog = {k_: np.asarray(v) for k_, v in model.ideal_params(batch).items()}
    analog.pop("noise_v")
    act_scale1 = np.float32(0.002)

    logits = np.asarray(model.mlp_cim(
        x_pad, w1p, w1n, b1, w2p, w2n, b2, act_scale1, analog,
        *_default_refs_trims()))
    assert logits.shape == (batch, 10)

    h = _nominal_tiled_layer(x, w1, 72)
    h = np.maximum(h + b1, 0.0)
    h_codes = np.clip(np.round(h * act_scale1), 0, P.CODE_MAX)
    ref_logits = _nominal_tiled_layer(h_codes, w2, 10) + b2

    # identical up to float .5-rounding ties inside the ADC model: a tie
    # flips one 6-bit code, i.e. 1/k in code-product units, per tile read.
    k = ref.code_gain_nominal()
    ties = np.abs(logits - ref_logits) / (1.0 / k)
    assert np.max(ties) <= 22 * 0.01 + 2.0  # at most a couple of tie flips
    agree = np.mean(np.argmax(logits, 1) == np.argmax(ref_logits, 1))
    assert agree >= 0.75


def test_mlp_errors_degrade_then_structure_remains():
    """Non-ideal params must change logits (the silicon gap) but keep them
    finite and shaped correctly."""
    rng = np.random.default_rng(5)
    batch = 4
    w1 = rng.integers(-15, 16, size=(784, 72)).astype(np.float32)
    w2 = rng.integers(-40, 41, size=(72, 10)).astype(np.float32)
    x = rng.integers(0, 20, size=(batch, 784)).astype(np.float32)
    x_pad = np.pad(x, ((0, 0), (0, 22 * P.N_ROWS - 784)))
    w1p, w1n = _tiled_weights(w1, 22, 3)
    w2p, w2n = _tiled_weights(w2, 2, 1)

    ideal = {k: np.asarray(v) for k, v in model.ideal_params(batch).items()}
    ideal.pop("noise_v")
    noisy = dict(rand_params(rng, batch, sigma_scale=1.5))
    noisy.pop("noise_v")

    la = np.asarray(model.mlp_cim(x_pad, w1p, w1n, np.zeros(72, np.float32),
                                  w2p, w2n, np.zeros(10, np.float32),
                                  np.float32(0.01), ideal,
                                  *_default_refs_trims()))
    lb = np.asarray(model.mlp_cim(x_pad, w1p, w1n, np.zeros(72, np.float32),
                                  w2p, w2n, np.zeros(10, np.float32),
                                  np.float32(0.01), noisy,
                                  *_default_refs_trims()))
    assert np.all(np.isfinite(lb))
    assert not np.allclose(la, lb)


def test_pad_batch_roundtrip():
    rng = np.random.default_rng(1)
    _, w_pos, w_neg = rand_weights(rng)
    p = rand_params(rng, 5)
    x = rng.integers(-63, 64, size=(5, P.N_ROWS)).astype(np.float32)
    from tests.util import args_list
    q = np.asarray(model.cim_apply(*args_list(x, w_pos, w_neg, p), tb=128))
    assert q.shape == (5, P.M_COLS)
