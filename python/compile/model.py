"""L2: JAX model of the Acore-CIM core and the MLP-on-CIM inference graph.

Two public entry points, both AOT-lowered by `aot.py`:

  * `cim_apply(...)` — one pass through the physical 36x32 array, taking the
    *raw* physical parameters (so the rust coordinator feeds exactly what its
    own golden model uses) and calling the Pallas kernel on the folded form.

  * `mlp_cim(...)` — the paper's MNIST MLP (784-72-10, Section VII-C) where
    every matmul is tile-scheduled onto the single physical array: row-tiles
    of 36 and column-tiles of 32, partial sums digitized at B_Q = 6 bits and
    accumulated digitally (the RISC-V core's job in the paper), bias + ReLU
    applied digitally, activations re-quantized to input codes per layer.

Parameter conventions match `rust/src/analog/` (see kernels/ref.py docstring).
"""

import jax
import jax.numpy as jnp

from . import params as P
from .kernels import cim_mac as K
from .kernels import ref


def fold_params(w_pos, w_neg, dac_gain, dac_off, cell_delta,
                alpha_p, alpha_n, beta, gamma3, rsa_p, rsa_n, vcal,
                adc_consts):
    """Fold physical parameters into the kernel's algebraic form.

    Returns (g_pos, g_neg, qa, qb, qc, qd, qm) — see kernels/cim_mac.py.
    The column attenuation factor (kappa_in, Fig. 1 effect 4) is separable
    from the row term, so it folds into the per-column epilogue; the row
    regulation droop (kappa_reg, effect 5) folds into the conductances.
    The cubic distortion v + gamma3*(v - V_BIAS)^3 folds into code units:
        q = q_lin + qd*(q_lin - qm)^3,
        qd = gamma3 / A^2,  qm = A*(V_BIAS - v_l) + beta_d,  A = alpha_d*C_ADC
    (the linear SA output in code units is q_lin = A*(v_lin - v_l) + beta_d,
    so v_lin - V_BIAS = (q_lin - qm)/A).
    """
    alpha_d, beta_d, v_l, _v_h, kappa_in, kappa_reg = (
        adc_consts[0], adc_consts[1], adc_consts[2],
        adc_consts[3], adc_consts[4], adc_consts[5],
    )
    c_adc = P.ADC_MAX / (adc_consts[3] - v_l)
    g_pos, g_neg = ref.conductances(w_pos, w_neg, cell_delta, kappa_reg)
    colfac = 1.0 - kappa_in * jnp.arange(P.M_COLS) / (P.M_COLS - 1)
    a = alpha_d * c_adc
    scale = a * colfac
    qa = scale * alpha_p * rsa_p
    qb = scale * alpha_n * rsa_n
    qc = a * (vcal + beta - v_l) + beta_d
    qd = gamma3 / (a * a) * jnp.ones(P.M_COLS)
    qm = (a * (P.V_BIAS - v_l) + beta_d) * jnp.ones(P.M_COLS)
    return g_pos, g_neg, qa, qb, qc, qd, qm


def fold_inputs(x, dac_gain, dac_off):
    """Fold the input-DAC transfer into effective voltages (X_eff)."""
    return ref.dac_transfer(x, dac_gain, dac_off)


def fold_noise(noise_v, adc_consts):
    """SA-referred noise [V] -> ADC-code units for the kernel epilogue."""
    c_adc = P.ADC_MAX / (adc_consts[3] - adc_consts[2])
    return noise_v * adc_consts[0] * c_adc


def _pad_batch(x, tb):
    b = x.shape[0]
    pad = (-b) % tb
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
    return x, b


def cim_apply(x, w_pos, w_neg, dac_gain, dac_off, cell_delta,
              alpha_p, alpha_n, beta, gamma3, rsa_p, rsa_n, vcal,
              adc_consts, noise_v, *, tb=K.DEFAULT_TB):
    """One batched pass through the physical array: raw params -> ADC codes."""
    g_pos, g_neg, qa, qb, qc, qd, qm = fold_params(
        w_pos, w_neg, dac_gain, dac_off, cell_delta,
        alpha_p, alpha_n, beta, gamma3, rsa_p, rsa_n, vcal, adc_consts)
    x_eff = fold_inputs(x, dac_gain, dac_off)
    q_noise = fold_noise(noise_v, adc_consts)
    x_eff, b = _pad_batch(x_eff, tb)
    q_noise, _ = _pad_batch(q_noise, tb)
    q = K.cim_mac(x_eff, g_pos, g_neg, qa, qb, qc, qd, qm, q_noise, tb=tb)
    return q[:b]


# ---------------------------------------------------------------------------
# MLP-on-CIM (paper Section VII-C): 784I - 72H - 10O on MNIST
# ---------------------------------------------------------------------------

def tile_counts(rows, cols):
    """Row/column tile counts for mapping a (rows x cols) matmul onto the
    36x32 physical array."""
    rt = -(-rows // P.N_ROWS)
    ct = -(-cols // P.M_COLS)
    return rt, ct


def _layer_on_cim(x_codes, wt_pos, wt_neg, analog, cols, vadc, trim_g,
                  trim_eps):
    """One DNN layer executed tile-by-tile on the physical array.

    x_codes: [B, rt*N] zero-padded input codes.
    wt_pos/wt_neg: [rt, ct, N, M] pre-tiled weight magnitudes.
    analog: dict of the physical error/trim parameters (shared by every
            tile — there is ONE physical array, time-multiplexed).
    cols:   true output width (<= ct*M).
    vadc:   [2] this layer's ADC reference window (v_l, v_h) — the
            dynamic-range management of DESIGN.md §6.
    trim_g/trim_eps: [M] digital residual correction (RISC-V side):
            q' = (q - eps)/g; pass (ones, zeros) to disable.

    Returns [B, cols] *digitally accumulated* MAC estimate in code-product
    units: the RISC-V side corrects each 6-bit partial with the digital
    trims, dequantizes with the NOMINAL transfer constants at this window,
    and sums across row tiles.
    """
    rt, ct = wt_pos.shape[0], wt_pos.shape[1]
    b = x_codes.shape[0]
    v_l, v_h = vadc[0], vadc[1]
    c_adc = P.ADC_MAX / (v_h - v_l)
    lsb_in = P.V_SWING / (1 << P.B_D)
    k = c_adc * P.R_SA_NOM * lsb_in / (P.R_U * (1 << P.B_W))
    mid = c_adc * (P.V_CAL_NOM - v_l)
    zero_noise = jnp.zeros((b, P.M_COLS), jnp.float32)
    consts = analog["adc_consts"]
    adc_consts = jnp.concatenate(
        [consts[:2], jnp.stack([v_l, v_h]), consts[4:]])

    def per_tile(r, c):
        xr = jax.lax.dynamic_slice_in_dim(x_codes, r * P.N_ROWS, P.N_ROWS, 1)
        q = cim_apply(xr, wt_pos[r, c], wt_neg[r, c], analog["dac_gain"],
                      analog["dac_off"], analog["cell_delta"],
                      analog["alpha_p"], analog["alpha_n"], analog["beta"],
                      analog["gamma3"], analog["rsa_p"], analog["rsa_n"],
                      analog["vcal"], adc_consts, zero_noise)
        q = (q - trim_eps) / trim_g               # digital residual trim
        return (q - mid) / k                      # digital dequantization

    col_blocks = []
    for c in range(ct):
        acc = jnp.zeros((b, P.M_COLS), jnp.float32)
        for r in range(rt):
            acc = acc + per_tile(r, c)
        col_blocks.append(acc)
    return jnp.concatenate(col_blocks, axis=1)[:, :cols]


def _quantize_acts(a, scale):
    """Digital re-quantization of activations to input codes (0..63 —
    post-ReLU activations are non-negative, like MNIST pixels)."""
    return jnp.clip(jnp.round(a * scale), 0.0, float(P.CODE_MAX))


def mlp_cim(x_codes, w1_pos, w1_neg, b1_codes, w2_pos, w2_neg, b2_codes,
            act_scale1, analog, vadc1, vadc2, trim1_g, trim1_eps, trim2_g,
            trim2_eps):
    """784-72-10 MLP forward, every matmul through the CIM array.

    x_codes:   [B, 792] pixel codes 0..63, zero-padded from 784 to 22*36.
    w1_pos/neg: [22, 3, 36, 32] layer-1 tiled weight magnitudes.
    b1_codes:  [72] layer-1 bias in code-product units.
    w2_pos/neg: [2, 1, 36, 32] layer-2 tiles (72 rows padded to 2*36).
    b2_codes:  [10] layer-2 bias in code-product units.
    act_scale1: scalar — hidden activation re-quantization scale.
    analog:    physical parameter dict (see _layer_on_cim).
    vadc1/vadc2: [2] per-layer ADC reference windows.
    trim*_g/eps: [32] per-layer digital residual trims (ones/zeros = off).

    Returns logits [B, 10] in layer-2 code-product units.
    """
    h = _layer_on_cim(x_codes, w1_pos, w1_neg, analog, 72, vadc1, trim1_g,
                      trim1_eps)
    h = jnp.maximum(h + b1_codes, 0.0)            # bias + ReLU, digital
    h_codes = _quantize_acts(h, act_scale1)
    h_pad = jnp.pad(h_codes, ((0, 0), (0, 2 * P.N_ROWS - 72)))
    logits = _layer_on_cim(h_pad, w2_pos, w2_neg, analog, 10, vadc2,
                           trim2_g, trim2_eps)
    return logits + b2_codes


def ideal_params(batch):
    """Error-free physical parameters (the 'simulation' row of §VII-C)."""
    f32 = jnp.float32
    return dict(
        dac_gain=jnp.ones(P.N_ROWS, f32),
        dac_off=jnp.zeros(P.N_ROWS, f32),
        cell_delta=jnp.zeros((P.N_ROWS, P.M_COLS), f32),
        alpha_p=jnp.ones(P.M_COLS, f32),
        alpha_n=jnp.ones(P.M_COLS, f32),
        beta=jnp.zeros(P.M_COLS, f32),
        gamma3=jnp.zeros(P.M_COLS, f32),
        rsa_p=jnp.full((P.M_COLS,), P.R_SA_NOM, f32),
        rsa_n=jnp.full((P.M_COLS,), P.R_SA_NOM, f32),
        vcal=jnp.full((P.M_COLS,), P.V_CAL_NOM, f32),
        adc_consts=jnp.array(
            [1.0, 0.0, P.V_ADC_L, P.V_ADC_H, 0.0, 0.0], f32),
        noise_v=jnp.zeros((batch, P.M_COLS), f32),
    )
