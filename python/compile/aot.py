"""AOT lowering: JAX model -> HLO *text* artifacts for the rust runtime.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` rust crate v0.1.6) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (shapes are baked into HLO, so we emit one per batch size):

  cim_mac_b{B}.hlo.txt   — one pass through the 36x32 array, raw physical
                           parameters as runtime inputs (14 operands).
  mlp_cim_b{B}.hlo.txt   — full 784-72-10 MLP with every matmul through the
                           CIM array (22x3 + 2x1 tiles), weights/biases and
                           the physical parameter bundle as runtime inputs.

Input operand order is the positional order of the python signatures below;
`rust/src/runtime/signature.rs` mirrors it.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, params as P

CIM_BATCHES = (1, 8, 32, 128, 256, 1024)
MLP_BATCHES = (1, 64, 256)

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, F32)


def cim_mac_fn(x, w_pos, w_neg, dac_gain, dac_off, cell_delta,
               alpha_p, alpha_n, beta, gamma3, rsa_p, rsa_n, vcal,
               adc_consts, noise_v):
    return (model.cim_apply(
        x, w_pos, w_neg, dac_gain, dac_off, cell_delta,
        alpha_p, alpha_n, beta, gamma3, rsa_p, rsa_n, vcal, adc_consts,
        noise_v),)


def cim_mac_specs(batch):
    n, m = P.N_ROWS, P.M_COLS
    return (
        _spec((batch, n)), _spec((n, m)), _spec((n, m)),
        _spec((n,)), _spec((n,)), _spec((n, m)),
        _spec((m,)), _spec((m,)), _spec((m,)), _spec((m,)),
        _spec((m,)), _spec((m,)), _spec((m,)),
        _spec((6,)), _spec((batch, m)),
    )


def mlp_fn(x_codes, w1_pos, w1_neg, b1, w2_pos, w2_neg, b2, act_scale1,
           dac_gain, dac_off, cell_delta, alpha_p, alpha_n, beta, gamma3,
           rsa_p, rsa_n, vcal, adc_consts, vadc1, vadc2,
           trim1_g, trim1_eps, trim2_g, trim2_eps):
    analog = dict(dac_gain=dac_gain, dac_off=dac_off, cell_delta=cell_delta,
                  alpha_p=alpha_p, alpha_n=alpha_n, beta=beta, gamma3=gamma3,
                  rsa_p=rsa_p, rsa_n=rsa_n, vcal=vcal, adc_consts=adc_consts)
    return (model.mlp_cim(x_codes, w1_pos, w1_neg, b1, w2_pos, w2_neg, b2,
                          act_scale1, analog, vadc1, vadc2,
                          trim1_g, trim1_eps, trim2_g, trim2_eps),)


def mlp_specs(batch):
    n, m = P.N_ROWS, P.M_COLS
    return (
        _spec((batch, 22 * n)),
        _spec((22, 3, n, m)), _spec((22, 3, n, m)), _spec((72,)),
        _spec((2, 1, n, m)), _spec((2, 1, n, m)), _spec((10,)),
        _spec(()),
        _spec((n,)), _spec((n,)), _spec((n, m)),
        _spec((m,)), _spec((m,)), _spec((m,)), _spec((m,)),
        _spec((m,)), _spec((m,)), _spec((m,)),
        _spec((6,)),
        _spec((2,)), _spec((2,)),
        _spec((m,)), _spec((m,)), _spec((m,)), _spec((m,)),
    )


def emit(out_dir: str, name: str, fn, specs) -> dict:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "path": os.path.basename(path),
        "num_inputs": len(specs),
        "input_shapes": [list(s.shape) for s in specs],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "bytes": len(text),
    }
    print(f"  {name}: {len(text)} chars, {len(specs)} inputs")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-mlp", action="store_true",
                    help="emit only the cim_mac artifacts (fast)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"artifacts": [], "params": {
        "N": P.N_ROWS, "M": P.M_COLS, "B_D": P.B_D, "B_W": P.B_W,
        "B_Q": P.B_Q, "R_U": P.R_U, "R_SA_NOM": P.R_SA_NOM,
        "V_INL": P.V_INL, "V_INH": P.V_INH, "V_BIAS": P.V_BIAS,
    }}
    print("emitting cim_mac artifacts:")
    for b in CIM_BATCHES:
        manifest["artifacts"].append(
            emit(args.out_dir, f"cim_mac_b{b}", cim_mac_fn, cim_mac_specs(b)))
    if not args.skip_mlp:
        print("emitting mlp_cim artifacts:")
        for b in MLP_BATCHES:
            manifest["artifacts"].append(
                emit(args.out_dir, f"mlp_cim_b{b}", mlp_fn, mlp_specs(b)))
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
