"""Pure-jnp oracle for the mixed-signal CIM MAC transfer function.

This is the *explicit* (unfolded, per-cell) evaluation of the analog path of
the Acore-CIM core — Fig. 1 / Eq. (2)-(4) of the paper:

    input codes --(R-2R input DACs, per-row gain/offset)--> V_DAC
    V_DAC --(row-wire attenuation, per-column)------------> V_IN(r, c)
    V_IN  --(MWC conductances w/ mismatch + V_REG droop)--> I_MAC+(c), I_MAC-(c)
    I     --(2SA: trims R_SA, V_CAL; errors alpha, beta)--> V_SA(c)
    V_SA  --(flash ADC: alpha_D, beta_D, refs, clip)------> Q_hat(c)

The Pallas kernel (`cim_mac.py`) implements the algebraically *folded* form
of the same function; `tests/test_kernel.py` asserts exact agreement.
The rust golden model (`rust/src/analog/`) implements this same math and is
checked bit-exact against the AOT artifact in `rust/tests/parity.rs`.
"""

import jax.numpy as jnp

from .. import params as P


def dac_transfer(x, dac_gain, dac_off):
    """Input R-2R MDAC: signed code -> differential output voltage (V_DAC - V_BIAS).

    x: [..., N] signed codes in [-2^B_D+1, 2^B_D-1].
    dac_gain/dac_off: [N] per-row gain error (~1) and additive offset [V].
    """
    lsb = P.V_SWING / (1 << P.B_D)
    return dac_gain * x * lsb + dac_off


def conductances(w_pos, w_neg, cell_delta, kappa_reg):
    """MWC conductance matrices for the positive/negative summation lines.

    w_pos/w_neg: [N, M] weight magnitudes (0..63) routed to I+ / I- lines.
    cell_delta: [N, M] fractional conductance mismatch.
    Returns (g_pos, g_neg): effective conductance [S] including the V_REG
    regulation droop along rows (Fig. 1, effect 5) as a row-dependent factor.
    """
    rowfac = 1.0 - kappa_reg * jnp.arange(P.N_ROWS) / (P.N_ROWS - 1)
    base = (1.0 + cell_delta) * rowfac[:, None] / (P.R_U * (1 << P.B_W))
    return w_pos * base, w_neg * base


def cim_forward(
    x,
    w_pos,
    w_neg,
    dac_gain,
    dac_off,
    cell_delta,
    alpha_p,
    alpha_n,
    beta,
    gamma3,
    rsa_p,
    rsa_n,
    vcal,
    adc_consts,
    noise_v,
):
    """Full mixed-signal forward: input codes -> ADC codes.

    x:          [B, N] signed input codes (float32).
    w_pos/neg:  [N, M] weight magnitudes on the +/- lines.
    dac_gain/dac_off: [N].
    cell_delta: [N, M].
    alpha_p/alpha_n/beta: [M] 2SA gain errors (positive/negative line) and
                offset [V] (combined SA1+SA2 input-referred).
    gamma3:     [M] 2SA cubic distortion coefficient [V^-2] — the
                uncorrectable nonlinearity that sets the post-BISC residual
                floor (Section II-C "a residual random error floor remains").
    rsa_p/rsa_n: [M] trimmed transresistances [Ohm] (digital potentiometer).
    vcal:       [M] trimmed calibration voltage [V] (6-bit cal DAC).
    adc_consts: [6] = [alpha_d, beta_d, v_adc_l, v_adc_h, kappa_in, kappa_reg].
    noise_v:    [B, M] additive noise sample at the SA output [V].

    Returns (q_hat, v_sa): quantized codes [B, M] and pre-ADC voltages
    (post-distortion, pre-noise).
    """
    alpha_d, beta_d, v_l, v_h, kappa_in, kappa_reg = (
        adc_consts[0], adc_consts[1], adc_consts[2],
        adc_consts[3], adc_consts[4], adc_consts[5],
    )
    # 1) input DACs
    v_dac = dac_transfer(x, dac_gain, dac_off)            # [B, N] (differential)
    # 2) row-wire attenuation toward far columns (effect 4)
    colfac = 1.0 - kappa_in * jnp.arange(P.M_COLS) / (P.M_COLS - 1)   # [M]
    v_in = v_dac[:, :, None] * colfac[None, None, :]      # [B, N, M]
    # 3) MWC currents and per-line accumulation (Eq. 3)
    g_pos, g_neg = conductances(w_pos, w_neg, cell_delta, kappa_reg)
    i_pos = jnp.sum(v_in * g_pos[None], axis=1)           # [B, M]
    i_neg = jnp.sum(v_in * g_neg[None], axis=1)
    # 4) 2SA with separate positive/negative line gains (Section VI-D)
    v_lin = (
        vcal
        + alpha_p * rsa_p * i_pos
        - alpha_n * rsa_n * i_neg
        + beta
    )
    # 4b) amplifier cubic distortion around the analog zero level
    v_sa = v_lin + gamma3 * (v_lin - P.V_BIAS) ** 3
    # 5) flash ADC (Eq. 2 with gain/offset errors, Eq. 8)
    c_adc = P.ADC_MAX / (v_h - v_l)
    q = alpha_d * c_adc * (v_sa + noise_v - v_l) + beta_d
    q_hat = jnp.clip(jnp.round(q), 0.0, float(P.ADC_MAX))
    return q_hat, v_sa


def q_nominal(x, w_signed):
    """Ideal (error-free, unquantized) column output Q_nom of Eq. (7).

    x: [B, N] signed input codes; w_signed: [N, M] signed weight codes.
    Returns [B, M] ideal output in ADC-code units (continuous).
    """
    s = x @ w_signed                                       # code-product sum
    lsb_in = P.V_SWING / (1 << P.B_D)
    i_mac = s * lsb_in / (P.R_U * (1 << P.B_W))
    c_adc = P.ADC_MAX / (P.V_ADC_H - P.V_ADC_L)
    return c_adc * (P.R_SA_NOM * i_mac + P.V_CAL_NOM - P.V_ADC_L)


def code_gain_nominal() -> float:
    """Nominal ADC codes per unit code-product sum (dQ/dS)."""
    lsb_in = P.V_SWING / (1 << P.B_D)
    c_adc = P.ADC_MAX / (P.V_ADC_H - P.V_ADC_L)
    return float(c_adc * P.R_SA_NOM * lsb_in / (P.R_U * (1 << P.B_W)))


def q_mid_nominal() -> float:
    """Nominal ADC code for zero MAC value."""
    c_adc = P.ADC_MAX / (P.V_ADC_H - P.V_ADC_L)
    return float(c_adc * (P.V_CAL_NOM - P.V_ADC_L))
