"""Pallas kernel for the batched mixed-signal CIM MAC — the compute hot-spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hot path is
an *analog* resistor crossbar; on TPU the same transfer function folds into
two MXU matmuls with element-wise pre/post epilogues, all fused in one
VMEM-resident pass:

    q_lin = (X_eff @ G_pos) * qa - (X_eff @ G_neg) * qb + qc
    q     = clip(round( q_lin + qd * (q_lin - qm)**3 + q_noise ), 0, 63)

where the *folding* of the physical parameters (DAC gains/offsets, parasitic
attenuation factors, mismatch, SA trims and errors, ADC transfer) into
(X_eff, G_pos, G_neg, qa, qb, qc) is done by the surrounding JAX model
(`model.py::fold_params`), which XLA fuses around the kernel.

BlockSpec schedule: the batch is tiled into TB-row blocks streamed
HBM->VMEM; the 36x32 conductance matrices (4.6 KiB each in f32) and the
per-column epilogue vectors stay VMEM-resident across the whole grid —
this is the analog array being "programmed once, pulsed per sample",
i.e. the S&H schedule of the paper expressed as a BlockSpec.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params as P

# Batch tile height. 128 aligns with the MXU/VPU lane structure on real
# TPUs; under interpret=True it simply bounds the working set.
DEFAULT_TB = 128


def _cim_mac_kernel(x_ref, gpos_ref, gneg_ref, qa_ref, qb_ref, qc_ref,
                    qd_ref, qm_ref, qn_ref, out_ref):
    """One batch-tile of the folded CIM transfer function.

    x_ref:   [TB, N]  effective input voltages (differential, folded DAC)
    gpos/gneg_ref: [N, M] folded conductances (+ and - summation lines)
    qa/qb/qc_ref:  [1, M] per-column epilogue affine coefficients
    qd/qm_ref:     [1, M] folded cubic-distortion coefficient and center
    qn_ref:  [TB, M] additive noise, pre-folded into ADC-code units
    out_ref: [TB, M] quantized ADC codes
    """
    x = x_ref[...]
    # Two MXU matmuls: the positive and negative accumulation lines of the
    # 2SA stage. f32 accumulation mirrors the analog current summation.
    i_pos = jnp.dot(x, gpos_ref[...], preferred_element_type=jnp.float32)
    i_neg = jnp.dot(x, gneg_ref[...], preferred_element_type=jnp.float32)
    # Per-column affine epilogue: SA trims/errors + ADC transfer, folded.
    q_lin = i_pos * qa_ref[...] - i_neg * qb_ref[...] + qc_ref[...]
    # Amplifier cubic distortion, folded into code units.
    t = q_lin - qm_ref[...]
    q = q_lin + qd_ref[...] * t * t * t + qn_ref[...]
    # Flash ADC: mid-tread rounding with clipping at the references.
    out_ref[...] = jnp.clip(jnp.round(q), 0.0, float(P.ADC_MAX))


@functools.partial(jax.jit, static_argnames=("tb",))
def cim_mac(x_eff, g_pos, g_neg, qa, qb, qc, qd, qm, q_noise, *, tb=DEFAULT_TB):
    """Batched folded CIM MAC via pallas_call.

    x_eff:   [B, N] f32 — B must be a multiple of `tb` (model.py pads).
    g_pos/g_neg: [N, M] f32.
    qa/qb/qc/qd/qm: [M] f32 per-column epilogue coefficients.
    q_noise: [B, M] f32.
    Returns  [B, M] f32 ADC codes.
    """
    b, n = x_eff.shape
    m = g_pos.shape[1]
    assert b % tb == 0, f"batch {b} not a multiple of tile {tb}"
    grid = (b // tb,)
    # Per-column vectors as [1, M] so they broadcast against [TB, M] tiles.
    qa2, qb2, qc2, qd2, qm2 = (v.reshape(1, m) for v in (qa, qb, qc, qd, qm))
    return pl.pallas_call(
        _cim_mac_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),     # stream batch tiles
            pl.BlockSpec((n, m), lambda i: (0, 0)),      # weights resident
            pl.BlockSpec((n, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),      # epilogue resident
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(x_eff, g_pos, g_neg, qa2, qb2, qc2, qd2, qm2, q_noise)
