"""Shared physical/architectural constants of the Acore-CIM core.

These mirror `rust/src/analog/consts.rs` — the two MUST stay in sync; the
integration test `rust/tests/parity.rs` executes the AOT artifact and the
rust golden model on identical inputs and asserts bit-exact ADC codes.

All values come from the paper (Sections III-IV, Alg. 1):
  * 36 x 32 MWC array, B_D = 6(+sign), B_W = 6(+2 sign), B_Q = 6
  * V_INL = 0.2 V, V_INH = 0.6 V, V_BIAS = 0.4 V
  * R_U = 385 kOhm (polysilicon baseline, Table I)
  * R_SA default = R_U / N ~= 10.7 kOhm (Alg. 1 / Fig. 7)
  * T_S&H = 1 us, ADC at M/T_S&H = 32 MHz
"""

N_ROWS = 36          # N: input rows
M_COLS = 32          # M: output columns
B_D = 6              # input magnitude bits (plus 1 sign bit)
B_W = 6              # weight magnitude bits (plus 2 sign bits W6/W7)
B_Q = 6              # ADC output bits
CODE_MAX = (1 << B_D) - 1          # 63
ADC_MAX = (1 << B_Q) - 1           # 63

V_INL = 0.2          # low input reference [V]
V_INH = 0.6          # high input reference [V]
V_BIAS = 0.4         # analog zero level [V]
V_SWING = V_INH - V_BIAS           # 0.2 V single-sided DAC swing

R_U = 385.0e3        # unit resistance of the R-2R ladders [Ohm]
R_SA_NOM = R_U / N_ROWS            # nominal 2SA transresistance ~10.69 kOhm
V_CAL_NOM = (V_INL + V_INH) / 2.0  # nominal calibration voltage = V_BIAS

V_ADC_L = V_INL      # default ADC references (Section III-B)
V_ADC_H = V_INH
T_SH = 1.0e-6        # S&H / inference period [s]
F_INF = 1.0 / T_SH   # 1 MHz inference frequency

# Structural (deterministic) parasitic knobs of Fig. 1.  kappa_in models the
# progressive input-voltage attenuation across columns (effect 4); kappa_reg
# models the summation-node regulation droop across rows (effect 5).  Both
# are fractional losses at the far end of the wire.
KAPPA_IN_DEFAULT = 0.02
KAPPA_REG_DEFAULT = 0.015


def adc_conv_factor(v_l: float = V_ADC_L, v_h: float = V_ADC_H) -> float:
    """C_ADC of Eq. (7): (2^B_Q - 1) / (V_H - V_L)."""
    return ADC_MAX / (v_h - v_l)
